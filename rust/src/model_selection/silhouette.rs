//! Algorithm 6: distributed silhouette statistics.
//!
//! Quantifies the stability of the k clusters produced by the custom
//! clustering. Each cluster c has r members (column c of each aligned
//! perturbation solution). Cohesion I: mean cosine **distance** within a
//! cluster; separation J: minimum over other clusters of the mean distance
//! to that cluster. Silhouette s = (J − I)/max(J, I) ∈ [−1, 1]; +1 =
//! disjoint stable clusters (paper §4.4 uses cosine distance).
//!
//! All inner products are computed blockwise and summed over the column
//! sub-communicator — the same one-collective-per-similarity-tensor
//! structure as Algorithm 6 (lines 5 and 15).

use crate::comm::{CommOp, CommResult, Group, Trace};
use crate::tensor::Mat;

/// Silhouette summary for one k.
#[derive(Clone, Debug)]
pub struct Silhouettes {
    /// Per-cluster mean silhouette (length k).
    pub per_cluster: Vec<f32>,
    /// Minimum silhouette width over clusters (the paper's headline `s_k`).
    pub min: f32,
    /// Average silhouette width.
    pub avg: f32,
}

/// Compute distributed silhouettes for this rank's aligned row-block stack
/// (`aligned[q]` is the `n_local × k` block of perturbation q). `comm`
/// must contain exactly one rank per row block.
pub fn silhouette_rank(
    comm: &Group,
    aligned: &[Mat],
    trace: &mut Trace,
) -> CommResult<Silhouettes> {
    let r = aligned.len();
    assert!(r >= 1);
    let (_n_local, k) = aligned[0].shape();
    if k == 1 {
        // a single cluster has no "other" cluster: define s = 1 (perfectly
        // separated by convention), matching the stability curve starting
        // high at k=1
        return Ok(Silhouettes { per_cluster: vec![1.0], min: 1.0, avg: 1.0 });
    }

    // ---- global column norms (needed to turn inner products into cosines)
    // norms²[q][c] summed over row blocks
    let mut norm_buf = vec![0f32; r * k];
    for (q, a_q) in aligned.iter().enumerate() {
        for i in 0..a_q.rows() {
            let row = a_q.row(i);
            for (c, &v) in row.iter().enumerate() {
                norm_buf[q * k + c] += v * v;
            }
        }
    }
    trace.record_comm(CommOp::ColumnReduce, comm, || comm.all_reduce_sum(&mut norm_buf))?;
    let norm = |q: usize, c: usize| norm_buf[q * k + c].max(1e-30).sqrt();

    // ---- inner products between all (q, c) pairs, one all_reduce:
    // buf[(c1*k + c2)*r*r + q1*r + q2] = <A_q1[:,c1], A_q2[:,c2]>
    // (the paper does k + k² separate r×r reductions; we fuse into one
    // buffer but the reduced volume is identical)
    let mut sim = vec![0f32; k * k * r * r];
    trace.record(CommOp::Silhouette, 0, || {
        for c1 in 0..k {
            for c2 in 0..k {
                for q1 in 0..r {
                    for q2 in 0..r {
                        let a1 = &aligned[q1];
                        let a2 = &aligned[q2];
                        let mut acc = 0f32;
                        for i in 0..a1.rows() {
                            acc += a1[(i, c1)] * a2[(i, c2)];
                        }
                        sim[(c1 * k + c2) * r * r + q1 * r + q2] = acc;
                    }
                }
            }
        }
    });
    trace.record_comm(CommOp::ColumnReduce, comm, || comm.all_reduce_sum(&mut sim))?;

    // cosine distance between member (q1 of cluster c1) and (q2 of c2)
    let dist = |c1: usize, q1: usize, c2: usize, q2: usize| -> f32 {
        let ip = sim[(c1 * k + c2) * r * r + q1 * r + q2];
        let cos = (ip / (norm(q1, c1) * norm(q2, c2))).clamp(-1.0, 1.0);
        1.0 - cos
    };

    // ---- I (cohesion) and J (separation) per member (q, c) ----
    let mut per_cluster = vec![0f32; k];
    let mut total = 0f32;
    let mut min_cluster = f32::INFINITY;
    for c in 0..k {
        let mut cluster_sum = 0f32;
        for q in 0..r {
            // I: mean distance to other members of cluster c
            let i_qc = if r > 1 {
                (0..r).filter(|&q2| q2 != q).map(|q2| dist(c, q, c, q2)).sum::<f32>()
                    / (r - 1) as f32
            } else {
                0.0
            };
            // J: min over other clusters of mean distance to that cluster
            let j_qc = (0..k)
                .filter(|&c2| c2 != c)
                .map(|c2| (0..r).map(|q2| dist(c, q, c2, q2)).sum::<f32>() / r as f32)
                .fold(f32::INFINITY, f32::min);
            let denom = j_qc.max(i_qc).max(1e-12);
            let s = (j_qc - i_qc) / denom;
            cluster_sum += s;
        }
        let mean_c = cluster_sum / r as f32;
        per_cluster[c] = mean_c;
        total += cluster_sum;
        min_cluster = min_cluster.min(mean_c);
    }
    Ok(Silhouettes { per_cluster, min: min_cluster, avg: total / (k * r) as f32 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::grid::run_on_grid;
    use crate::rng::Rng;

    fn group1() -> Group {
        Group::create(1).remove(0)
    }

    #[test]
    fn tight_distinct_clusters_score_high() {
        // r noisy copies of an orthogonal basis -> near-perfect silhouettes
        let mut rng = Rng::new(500);
        let n = 30;
        let k = 3;
        let r = 5;
        let mut base = Mat::zeros(n, k);
        for c in 0..k {
            for i in (c * 10)..(c * 10 + 10) {
                base[(i, c)] = 1.0;
            }
        }
        let stack: Vec<Mat> = (0..r)
            .map(|_| {
                Mat::from_fn(n, k, |i, j| base[(i, j)] * (1.0 + 0.01 * rng.uniform_f32()))
            })
            .collect();
        let mut trace = Trace::new();
        let s = silhouette_rank(&group1(), &stack, &mut trace).unwrap();
        assert!(s.min > 0.9, "min={}", s.min);
        assert!(s.avg > 0.9);
        assert_eq!(s.per_cluster.len(), 3);
    }

    #[test]
    fn random_clusters_score_low() {
        let mut rng = Rng::new(501);
        let stack: Vec<Mat> =
            (0..5).map(|_| Mat::random_uniform(30, 4, 0.0, 1.0, &mut rng)).collect();
        let mut trace = Trace::new();
        let s = silhouette_rank(&group1(), &stack, &mut trace).unwrap();
        assert!(s.min < 0.5, "min={}", s.min);
    }

    #[test]
    fn k1_is_one_by_convention() {
        let mut rng = Rng::new(502);
        let stack: Vec<Mat> =
            (0..3).map(|_| Mat::random_uniform(10, 1, 0.0, 1.0, &mut rng)).collect();
        let mut trace = Trace::new();
        let s = silhouette_rank(&group1(), &stack, &mut trace).unwrap();
        assert_eq!(s.min, 1.0);
    }

    #[test]
    fn distributed_matches_single_rank() {
        let mut rng = Rng::new(503);
        let n = 24;
        let k = 3;
        let r = 4;
        let full: Vec<Mat> =
            (0..r).map(|_| Mat::random_uniform(n, k, 0.0, 1.0, &mut rng)).collect();
        let mut trace = Trace::new();
        let want = silhouette_rank(&group1(), &full, &mut trace).unwrap();
        let results = run_on_grid(4, |ctx| {
            let (s, e) = ctx.grid.chunk(n, ctx.row);
            let stack: Vec<Mat> = full
                .iter()
                .map(|m| Mat::from_fn(e - s, k, |i, j| m[(s + i, j)]))
                .collect();
            let mut trace = Trace::new();
            silhouette_rank(&ctx.col_comm, &stack, &mut trace).unwrap()
        });
        for got in results {
            assert!((got.min - want.min).abs() < 1e-4, "{} vs {}", got.min, want.min);
            assert!((got.avg - want.avg).abs() < 1e-4);
        }
    }

    #[test]
    fn range_is_bounded() {
        let mut rng = Rng::new(504);
        for _ in 0..5 {
            let stack: Vec<Mat> =
                (0..4).map(|_| Mat::random_uniform(12, 3, 0.0, 1.0, &mut rng)).collect();
            let mut trace = Trace::new();
            let s = silhouette_rank(&group1(), &stack, &mut trace).unwrap();
            assert!(s.min >= -1.0 - 1e-5 && s.min <= 1.0 + 1e-5);
            assert!(s.avg >= -1.0 - 1e-5 && s.avg <= 1.0 + 1e-5);
        }
    }
}
