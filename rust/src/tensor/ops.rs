//! Elementwise multiplicative-update primitives and column utilities.
//!
//! These are the non-GEMM pieces of Equation 2 of the paper: the fused
//! `factor *= numerator / (denominator + ε)` update, column normalization
//! (the ‖A_i‖ = 1 constraint with inverse scaling folded into R), and the
//! cosine-similarity helpers used by clustering and silhouettes.

use super::dense::Mat;

/// ε guarding divisions, as in the paper (§2.2: ε ≈ 1e-16 in f64; we run
/// f32 so use the f32-representable equivalent).
pub const MU_EPS: f32 = 1e-16;

/// Fused multiplicative update: `target *= num / (deno + eps)`.
pub fn mu_update(target: &mut Mat, num: &Mat, deno: &Mat, eps: f32) {
    assert_eq!(target.shape(), num.shape());
    assert_eq!(target.shape(), deno.shape());
    let t = target.as_mut_slice();
    let n = num.as_slice();
    let d = deno.as_slice();
    for i in 0..t.len() {
        t[i] *= n[i] / (d[i] + eps);
    }
}

/// Column L2 norms of an n×k matrix.
pub fn col_norms(a: &Mat) -> Vec<f32> {
    let (n, k) = a.shape();
    let mut acc = vec![0.0f64; k];
    for i in 0..n {
        let row = a.row(i);
        for (j, &v) in row.iter().enumerate() {
            acc[j] += (v as f64) * (v as f64);
        }
    }
    acc.into_iter().map(|x| x.sqrt() as f32).collect()
}

/// Normalize columns of A to unit L2 norm, returning the scales. Columns
/// with zero norm are left untouched (scale 1).
pub fn normalize_cols(a: &mut Mat) -> Vec<f32> {
    let norms = col_norms(a);
    let (n, k) = a.shape();
    let scales: Vec<f32> = norms.iter().map(|&x| if x > 0.0 { x } else { 1.0 }).collect();
    for i in 0..n {
        let row = a.row_mut(i);
        for j in 0..k {
            row[j] /= scales[j];
        }
    }
    scales
}

/// Apply the inverse of a column scaling of A to a core slice R_t:
/// X ≈ A R Aᵀ = (A S⁻¹)(S R Sᵀ)(A S⁻¹)ᵀ, so R_t ← S R_t S.
pub fn rescale_core(r_t: &mut Mat, scales: &[f32]) {
    let (k, k2) = r_t.shape();
    assert_eq!(k, k2);
    assert_eq!(scales.len(), k);
    for i in 0..k {
        for j in 0..k {
            r_t[(i, j)] *= scales[i] * scales[j];
        }
    }
}

/// Cosine similarity between columns of M (n×k) and columns of A (n×k):
/// result[(i, j)] = cos(M[:,i], A[:,j]).
pub fn cosine_similarity(m: &Mat, a: &Mat) -> Mat {
    assert_eq!(m.rows(), a.rows());
    let mut sim = m.t_matmul(a); // MᵀA
    let mn = col_norms(m);
    let an = col_norms(a);
    for i in 0..sim.rows() {
        for j in 0..sim.cols() {
            let d = mn[i] * an[j];
            sim[(i, j)] = if d > 0.0 { sim[(i, j)] / d } else { 0.0 };
        }
    }
    sim
}

/// Clamp all entries below `floor` up to `floor` (keeps MU iterates strictly
/// positive so zero-locking cannot occur from numeric underflow).
pub fn clamp_min(a: &mut Mat, floor: f32) {
    for v in a.as_mut_slice() {
        if *v < floor {
            *v = floor;
        }
    }
}

/// True if every entry is finite and ≥ 0.
pub fn is_nonnegative(a: &Mat) -> bool {
    a.as_slice().iter().all(|&v| v.is_finite() && v >= 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    #[test]
    fn mu_update_basic() {
        let mut t = Mat::from_vec(1, 3, vec![2.0, 4.0, 8.0]);
        let num = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let deno = Mat::from_vec(1, 3, vec![2.0, 4.0, 6.0]);
        mu_update(&mut t, &num, &deno, 0.0);
        assert_close(t.as_slice(), &[1.0, 2.0, 4.0], 1e-6);
    }

    #[test]
    fn mu_update_preserves_nonnegativity() {
        let mut rng = Rng::new(20);
        let mut t = Mat::random_uniform(10, 10, 0.0, 1.0, &mut rng);
        let num = Mat::random_uniform(10, 10, 0.0, 1.0, &mut rng);
        let deno = Mat::random_uniform(10, 10, 0.0, 1.0, &mut rng);
        mu_update(&mut t, &num, &deno, MU_EPS);
        assert!(is_nonnegative(&t));
    }

    #[test]
    fn mu_update_eps_guards_zero_division() {
        let mut t = Mat::from_vec(1, 1, vec![1.0]);
        let num = Mat::from_vec(1, 1, vec![1.0]);
        let deno = Mat::from_vec(1, 1, vec![0.0]);
        mu_update(&mut t, &num, &deno, MU_EPS);
        assert!(t[(0, 0)].is_finite());
    }

    #[test]
    fn normalize_cols_unit_norm() {
        let mut rng = Rng::new(21);
        let mut a = Mat::random_uniform(20, 5, 0.1, 1.0, &mut rng);
        let orig = a.clone();
        let scales = normalize_cols(&mut a);
        for n in col_norms(&a) {
            assert!((n - 1.0).abs() < 1e-5);
        }
        // scales reproduce the original
        for j in 0..5 {
            for i in 0..20 {
                assert!((a[(i, j)] * scales[j] - orig[(i, j)]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn normalize_zero_column_is_noop() {
        let mut a = Mat::zeros(4, 2);
        a.set_col(1, &[3.0, 4.0, 0.0, 0.0]);
        let scales = normalize_cols(&mut a);
        assert_eq!(scales[0], 1.0);
        assert!((scales[1] - 5.0).abs() < 1e-6);
        assert_eq!(a.col(0), vec![0.0; 4]);
    }

    #[test]
    fn rescale_core_restores_product() {
        // (A S⁻¹)(S R S)(A S⁻¹)ᵀ == A R Aᵀ
        let mut rng = Rng::new(22);
        let a0 = Mat::random_uniform(6, 3, 0.1, 1.0, &mut rng);
        let r0 = Mat::random_uniform(3, 3, 0.1, 1.0, &mut rng);
        let want = a0.matmul(&r0).matmul_t(&a0);
        let mut a = a0.clone();
        let scales = normalize_cols(&mut a);
        let mut r = r0.clone();
        rescale_core(&mut r, &scales);
        let got = a.matmul(&r).matmul_t(&a);
        assert_close(got.as_slice(), want.as_slice(), 1e-4);
    }

    #[test]
    fn cosine_similarity_self_is_one_diag() {
        let mut rng = Rng::new(23);
        let a = Mat::random_uniform(30, 4, 0.1, 1.0, &mut rng);
        let sim = cosine_similarity(&a, &a);
        for i in 0..4 {
            assert!((sim[(i, i)] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cosine_similarity_orthogonal_cols() {
        let mut a = Mat::zeros(4, 2);
        a.set_col(0, &[1.0, 0.0, 0.0, 0.0]);
        a.set_col(1, &[0.0, 1.0, 0.0, 0.0]);
        let sim = cosine_similarity(&a, &a);
        assert!((sim[(0, 1)]).abs() < 1e-6);
        assert!((sim[(1, 0)]).abs() < 1e-6);
    }

    #[test]
    fn cosine_similarity_range() {
        let mut rng = Rng::new(24);
        let m = Mat::random_uniform(10, 3, -1.0, 1.0, &mut rng);
        let a = Mat::random_uniform(10, 5, -1.0, 1.0, &mut rng);
        let sim = cosine_similarity(&m, &a);
        for &v in sim.as_slice() {
            assert!(v >= -1.0 - 1e-5 && v <= 1.0 + 1e-5);
        }
    }

    #[test]
    fn clamp_min_floors() {
        let mut a = Mat::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        clamp_min(&mut a, 0.5);
        assert_eq!(a.as_slice(), &[0.5, 0.5, 2.0]);
    }
}
