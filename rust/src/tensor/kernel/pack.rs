//! Panel packing: copy operand blocks into microkernel order, widening
//! 16-bit storage to f32 on the way.
//!
//! Packing reads operands through a strided [`View`] — a transposed
//! operand is the same buffer with the strides swapped, and a
//! half-precision operand is the same loop over a `u16` buffer with a
//! widen per element ("widen-on-pack"). Both panels are zero-padded to
//! full `MR`/`NR` so the microkernel never branches on ragged tiles;
//! the pad lanes contribute exact FMA no-ops.

use super::View;

/// Pack the `mb×kb` block of A at `(ic, pc)` into `mr`-row micro-panels:
/// panel `ir/mr` holds `out[p*mr + i] = A[ic+ir+i, pc+p]`, zero-padded
/// to a full `mr`.
pub(super) fn pack_a(a: View, ic: usize, mb: usize, pc: usize, kb: usize, mr: usize, out: &mut [f32]) {
    let mut idx = 0;
    for ir in (0..mb).step_by(mr) {
        for p in 0..kb {
            for i in 0..mr {
                out[idx] = if ir + i < mb { a.at(ic + ir + i, pc + p) } else { 0.0 };
                idx += 1;
            }
        }
    }
}

/// Pack the `kb×nb` block of B at `(pc, jc)` into `nr`-column
/// micro-panels: panel `jr/nr` holds `out[p*nr + j] = B[pc+p, jc+jr+j]`,
/// zero-padded to a full `nr`.
pub(super) fn pack_b(b: View, pc: usize, kb: usize, jc: usize, nb: usize, nr: usize, out: &mut [f32]) {
    let mut idx = 0;
    for jr in (0..nb).step_by(nr) {
        for p in 0..kb {
            for j in 0..nr {
                out[idx] = if jr + j < nb { b.at(pc + p, jc + jr + j) } else { 0.0 };
                idx += 1;
            }
        }
    }
}
