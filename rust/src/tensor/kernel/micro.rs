//! Arch-specific `MR×NR` register-tile microkernels.
//!
//! Every kernel computes the identical floating-point dependency chain:
//! for each output element `(i, j)`,
//! `acc = fma(ap[p*MR + i], bp[p*NR + j], acc)` sequentially over
//! `p = 0..kb`, then `c[i*ldc + j] += acc`. The SIMD variants vectorize
//! only across the `j` lanes, never across `p`, so each element performs
//! the same fused multiply-adds in the same order as the scalar
//! reference (which uses [`f32::mul_add`] — a single rounding per step,
//! exactly an FMA) and all f32 variants agree **bit for bit**. Packed
//! zero padding contributes `fma(0, b, acc)` / `fma(a, 0, acc)` no-ops,
//! so ragged tiles keep the property.
//!
//! # Safety contract (all kernels)
//!
//! Callers guarantee that `ap` points at `kb*MR` packed f32 (an A
//! micro-panel), `bp` at `kb*NR` (a B micro-panel), `c` at an `MR×NR`
//! tile whose every row `i` spans `c[i*ldc .. i*ldc + NR]` in bounds —
//! and, for the SIMD variants, that the advertised CPU features are
//! present (verified once at startup by [`super::dispatch`]).

/// An accumulate-tile microkernel: `C[MR×NR] += Ap · Bp` over packed
/// micro-panels of depth `kb`, with C row stride `ldc`.
pub(crate) type TileFn =
    unsafe fn(kb: usize, ap: *const f32, bp: *const f32, c: *mut f32, ldc: usize);

/// Portable scalar reference tile. `mul_add` keeps it a true FMA chain,
/// so the vector kernels can match it bit for bit.
///
/// # Safety
/// See the module-level safety contract.
pub(crate) unsafe fn tile_scalar<const MR: usize, const NR: usize>(
    kb: usize,
    ap: *const f32,
    bp: *const f32,
    c: *mut f32,
    ldc: usize,
) {
    unsafe {
        let ap = std::slice::from_raw_parts(ap, kb * MR);
        let bp = std::slice::from_raw_parts(bp, kb * NR);
        let mut acc = [[0.0f32; NR]; MR];
        for p in 0..kb {
            let av = &ap[p * MR..p * MR + MR];
            let bv = &bp[p * NR..p * NR + NR];
            for i in 0..MR {
                let ai = av[i];
                for j in 0..NR {
                    acc[i][j] = ai.mul_add(bv[j], acc[i][j]);
                }
            }
        }
        for (i, arow) in acc.iter().enumerate() {
            let crow = std::slice::from_raw_parts_mut(c.add(i * ldc), NR);
            for (cv, &av) in crow.iter_mut().zip(arow.iter()) {
                *cv += av;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use std::arch::x86_64::*;

    /// 8×8 AVX2+FMA tile: one `__m256` accumulator per row of C, a
    /// broadcast of A per row and one B row load per depth step.
    ///
    /// # Safety
    /// The module-level contract, plus AVX2 and FMA support.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn tile_avx2_8x8(
        kb: usize,
        ap: *const f32,
        bp: *const f32,
        c: *mut f32,
        ldc: usize,
    ) {
        unsafe {
            let mut acc = [_mm256_setzero_ps(); 8];
            for p in 0..kb {
                let b = _mm256_loadu_ps(bp.add(p * 8));
                for i in 0..8 {
                    let a = _mm256_set1_ps(*ap.add(p * 8 + i));
                    acc[i] = _mm256_fmadd_ps(a, b, acc[i]);
                }
            }
            for i in 0..8 {
                let crow = c.add(i * ldc);
                _mm256_storeu_ps(crow, _mm256_add_ps(_mm256_loadu_ps(crow), acc[i]));
            }
        }
    }

    /// 8×16 AVX-512F tile: one `__m512` accumulator per row of C —
    /// double the lane width of the AVX2 tile, same chain per element.
    ///
    /// # Safety
    /// The module-level contract, plus AVX-512F support.
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn tile_avx512_8x16(
        kb: usize,
        ap: *const f32,
        bp: *const f32,
        c: *mut f32,
        ldc: usize,
    ) {
        unsafe {
            let mut acc = [_mm512_setzero_ps(); 8];
            for p in 0..kb {
                let b = _mm512_loadu_ps(bp.add(p * 16));
                for i in 0..8 {
                    let a = _mm512_set1_ps(*ap.add(p * 8 + i));
                    acc[i] = _mm512_fmadd_ps(a, b, acc[i]);
                }
            }
            for i in 0..8 {
                let crow = c.add(i * ldc);
                _mm512_storeu_ps(crow, _mm512_add_ps(_mm512_loadu_ps(crow), acc[i]));
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) mod arm {
    use std::arch::aarch64::*;

    /// 8×8 NEON tile: two `float32x4` accumulators per row of C.
    ///
    /// # Safety
    /// The module-level contract (NEON is baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn tile_neon_8x8(
        kb: usize,
        ap: *const f32,
        bp: *const f32,
        c: *mut f32,
        ldc: usize,
    ) {
        unsafe {
            let mut lo = [vdupq_n_f32(0.0); 8];
            let mut hi = [vdupq_n_f32(0.0); 8];
            for p in 0..kb {
                let b0 = vld1q_f32(bp.add(p * 8));
                let b1 = vld1q_f32(bp.add(p * 8 + 4));
                for i in 0..8 {
                    let a = vdupq_n_f32(*ap.add(p * 8 + i));
                    lo[i] = vfmaq_f32(lo[i], a, b0);
                    hi[i] = vfmaq_f32(hi[i], a, b1);
                }
            }
            for i in 0..8 {
                let crow = c.add(i * ldc);
                vst1q_f32(crow, vaddq_f32(vld1q_f32(crow), lo[i]));
                vst1q_f32(crow.add(4), vaddq_f32(vld1q_f32(crow.add(4)), hi[i]));
            }
        }
    }
}
