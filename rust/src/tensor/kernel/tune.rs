//! Per-machine blocking autotune (`drescal tune`).
//!
//! The MC/KC/NC loop blocking that best feeds a given microkernel
//! depends on the host's cache hierarchy, so it is not hard-coded:
//! [`sweep`] times the packed core over a grid of blocking candidates on
//! a fixed square GEMM and returns the winner, which `drescal tune`
//! persists as a small JSON profile (default [`PROFILE_FILE`], next to
//! the bench baseline). Every other subcommand calls [`autoload`] at
//! startup: if a profile is present **and** was tuned for the microkernel
//! variant active on this machine, its blocking is applied; a profile
//! tuned for a different ISA is ignored (the optimum does not transfer
//! across tile shapes).
//!
//! Blocking only changes the loop order of bitwise-identical microkernel
//! tile updates within a serial core, so a tuned profile never changes
//! GEMM results — only their speed.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::error::{Context, Error, Result};
use crate::json::Json;
use crate::rng::Rng;

use super::dispatch;
use super::Mat;

/// Default profile path, resolved relative to the working directory
/// (override with `--out` / `DRESCAL_TUNE_PROFILE`).
pub const PROFILE_FILE: &str = "KERNEL_tune.json";

/// A persisted autotune result: the winning blocking for one microkernel
/// variant on one machine.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneProfile {
    /// Name of the microkernel the sweep ran on ([`dispatch::KernelDesc::name`]).
    pub isa: String,
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
    /// Throughput of the winning point on the tuning shape.
    pub gflops: f64,
}

impl TuneProfile {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kind".to_string(), Json::Str("kernel_tune_profile".to_string()));
        o.insert("isa".to_string(), Json::Str(self.isa.clone()));
        o.insert("mc".to_string(), Json::Num(self.mc as f64));
        o.insert("kc".to_string(), Json::Num(self.kc as f64));
        o.insert("nc".to_string(), Json::Num(self.nc as f64));
        o.insert("gflops".to_string(), Json::Num(self.gflops));
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<TuneProfile> {
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != "kernel_tune_profile" {
            return Err(Error::msg(format!(
                "not a kernel tune profile (kind = {kind:?})"
            )));
        }
        let field = |name: &str| -> Result<usize> {
            match j.get(name).and_then(Json::as_usize) {
                Some(v) if v > 0 => Ok(v),
                _ => Err(Error::msg(format!("tune profile: bad or missing {name:?}"))),
            }
        };
        Ok(TuneProfile {
            isa: j
                .get("isa")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::msg("tune profile: missing isa"))?
                .to_string(),
            mc: field("mc")?,
            kc: field("kc")?,
            nc: field("nc")?,
            gflops: j.get("gflops").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing tune profile {path}"))
    }

    pub fn load(path: &str) -> Result<TuneProfile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune profile {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing tune profile {path}"))?;
        TuneProfile::from_json(&j)
    }

    /// Install this profile's blocking for subsequent GEMMs.
    pub fn apply(&self) {
        super::set_blocking(self.mc, self.kc, self.nc);
    }
}

/// One timed candidate from a sweep.
pub struct TunePoint {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
    pub gflops: f64,
}

/// Time the blocking grid on the active microkernel and return the
/// winner plus every timed point (for the report table). `quick` shrinks
/// the grid and the problem to a CI-friendly smoke. Restores whatever
/// blocking was installed before the sweep.
pub fn sweep(quick: bool) -> (TuneProfile, Vec<TunePoint>) {
    let kern = dispatch::active();
    let n = if quick { 192 } else { 384 };
    let reps = if quick { 2 } else { 3 };
    let (mcs, kcs, ncs): (&[usize], &[usize], &[usize]) = if quick {
        (&[64, 128], &[256], &[1024])
    } else {
        (&[32, 64, 128, 256], &[128, 256, 512], &[256, 512, 1024, 2048])
    };

    let mut rng = Rng::new(77);
    let a = Mat::random_uniform(n, n, -1.0, 1.0, &mut rng);
    let b = Mat::random_uniform(n, n, -1.0, 1.0, &mut rng);
    let mut c = Mat::zeros(n, n);
    let flops = 2.0 * (n as f64).powi(3);

    let saved = super::blocking();
    let mut points = Vec::new();
    let mut best: Option<TunePoint> = None;
    for &mc in mcs {
        for &kc in kcs {
            for &nc in ncs {
                super::set_blocking(mc, kc, nc);
                // warm the pack scratch (and the caches) outside the timer
                super::gemm_nn_into_with(kern, &a, &b, &mut c, false);
                let mut best_t = f64::INFINITY;
                for _ in 0..reps {
                    let t0 = Instant::now();
                    super::gemm_nn_into_with(kern, &a, &b, &mut c, false);
                    best_t = best_t.min(t0.elapsed().as_secs_f64());
                }
                let gflops = flops / best_t / 1e9;
                let better = match &best {
                    None => true,
                    Some(p) => gflops > p.gflops,
                };
                if better {
                    best = Some(TunePoint { mc, kc, nc, gflops });
                }
                points.push(TunePoint { mc, kc, nc, gflops });
            }
        }
    }
    super::set_blocking(saved.0, saved.1, saved.2);

    let w = best.expect("tune grid is never empty");
    let profile = TuneProfile {
        isa: kern.name.to_string(),
        mc: w.mc,
        kc: w.kc,
        nc: w.nc,
        gflops: w.gflops,
    };
    (profile, points)
}

/// Load and apply the machine's tune profile, if one exists and matches
/// the active microkernel. Returns the applied profile, or `None` when
/// there is no usable profile (missing file, parse error, or an ISA
/// mismatch — all silently fall back to the default blocking).
pub fn autoload() -> Option<TuneProfile> {
    let path = std::env::var("DRESCAL_TUNE_PROFILE").unwrap_or_else(|_| PROFILE_FILE.to_string());
    let profile = TuneProfile::load(&path).ok()?;
    if profile.isa != dispatch::active().name {
        return None;
    }
    profile.apply();
    Some(profile)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_round_trips_through_json() {
        let p = TuneProfile {
            isa: "scalar_8x8".to_string(),
            mc: 128,
            kc: 256,
            nc: 512,
            gflops: 12.5,
        };
        let back = TuneProfile::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn bad_profiles_are_rejected() {
        assert!(TuneProfile::from_json(&Json::parse("{}").unwrap()).is_err());
        let j = Json::parse(r#"{"kind":"kernel_tune_profile","isa":"x","mc":0,"kc":1,"nc":1}"#)
            .unwrap();
        assert!(TuneProfile::from_json(&j).is_err());
    }

    #[test]
    fn quick_sweep_returns_a_winner_and_restores_blocking() {
        let saved = super::super::blocking();
        let (profile, points) = sweep(true);
        assert_eq!(super::super::blocking(), saved, "sweep must restore blocking");
        assert!(!points.is_empty());
        assert_eq!(profile.isa, dispatch::active().name);
        assert!(profile.gflops > 0.0);
        assert!(points.iter().all(|p| profile.gflops >= p.gflops - 1e-9));
    }
}
