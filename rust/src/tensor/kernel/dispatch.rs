//! Runtime microkernel selection.
//!
//! The widest microkernel the host supports is picked **once**, on the
//! first GEMM (or an explicit [`active`] call), via
//! `is_x86_feature_detected!` — never per call. Two environment
//! overrides exist for CI and debugging:
//!
//! * `DRESCAL_FORCE_SCALAR=1` pins the portable scalar reference
//!   (CI runs the whole parity matrix under it);
//! * `DRESCAL_KERNEL=<name>` pins a specific variant by name; an
//!   unavailable name warns and falls back to auto-detection.
//!
//! The selected [`KernelDesc`] also carries the `'static` span label the
//! telemetry plane stamps on `gemm` phase spans (`gemm[avx2_fma_8x8]`,
//! …), so traces from different machines are attributable to the kernel
//! that produced them.

use std::sync::OnceLock;

use super::micro::{self, TileFn};

/// One selectable microkernel variant.
pub struct KernelDesc {
    /// Stable identifier (`scalar_8x8`, `avx2_fma_8x8`, `avx512f_8x16`,
    /// `neon_8x8`) — also the ISA tag of a tune profile.
    pub name: &'static str,
    /// Human-readable ISA description for bench headers.
    pub isa: &'static str,
    /// Telemetry phase label for GEMM spans (`Trace::phase_end` needs a
    /// `'static` string).
    pub gemm_label: &'static str,
    /// Register-tile height (rows of C per microkernel call).
    pub mr: usize,
    /// Register-tile width (columns of C per microkernel call).
    pub nr: usize,
    pub(crate) tile: TileFn,
}

static SCALAR: KernelDesc = KernelDesc {
    name: "scalar_8x8",
    isa: "portable scalar (mul_add)",
    gemm_label: "gemm[scalar_8x8]",
    mr: 8,
    nr: 8,
    tile: micro::tile_scalar::<8, 8>,
};

#[cfg(target_arch = "x86_64")]
static AVX2: KernelDesc = KernelDesc {
    name: "avx2_fma_8x8",
    isa: "x86-64 AVX2+FMA",
    gemm_label: "gemm[avx2_fma_8x8]",
    mr: 8,
    nr: 8,
    tile: micro::x86::tile_avx2_8x8,
};

#[cfg(target_arch = "x86_64")]
static AVX512: KernelDesc = KernelDesc {
    name: "avx512f_8x16",
    isa: "x86-64 AVX-512F",
    gemm_label: "gemm[avx512f_8x16]",
    mr: 8,
    nr: 16,
    tile: micro::x86::tile_avx512_8x16,
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelDesc = KernelDesc {
    name: "neon_8x8",
    isa: "aarch64 NEON",
    gemm_label: "gemm[neon_8x8]",
    mr: 8,
    nr: 8,
    tile: micro::arm::tile_neon_8x8,
};

/// Every variant this host can run, narrowest first (the scalar
/// reference is always present; the auto-detected choice is the last
/// entry). Parity tests iterate this list against the scalar reference.
pub fn variants() -> Vec<&'static KernelDesc> {
    #[allow(unused_mut)]
    let mut v: Vec<&'static KernelDesc> = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            v.push(&AVX2);
        }
        if std::arch::is_x86_feature_detected!("avx512f") {
            v.push(&AVX512);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        v.push(&NEON);
    }
    v
}

/// Look a variant up by its stable name (if available on this host).
pub fn by_name(name: &str) -> Option<&'static KernelDesc> {
    variants().into_iter().find(|k| k.name == name)
}

fn select() -> &'static KernelDesc {
    if std::env::var("DRESCAL_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
        return &SCALAR;
    }
    if let Ok(name) = std::env::var("DRESCAL_KERNEL") {
        match by_name(&name) {
            Some(k) => return k,
            None => eprintln!(
                "warning: DRESCAL_KERNEL={name} is not available on this host; auto-detecting"
            ),
        }
    }
    *variants().last().expect("the scalar kernel is always available")
}

static ACTIVE: OnceLock<&'static KernelDesc> = OnceLock::new();

/// The microkernel every GEMM entry point runs on, selected once per
/// process.
pub fn active() -> &'static KernelDesc {
    ACTIVE.get_or_init(select)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_listed_and_named() {
        let v = variants();
        assert_eq!(v[0].name, "scalar_8x8");
        assert!(by_name("scalar_8x8").is_some());
        assert!(by_name("not_a_kernel").is_none());
        for k in &v {
            assert!(k.mr <= super::super::MR_MAX && k.nr <= super::super::NR_MAX);
            assert!(k.gemm_label.starts_with("gemm["));
        }
    }

    #[test]
    fn active_is_one_of_the_variants() {
        let a = active();
        assert!(variants().iter().any(|k| k.name == a.name));
    }
}
