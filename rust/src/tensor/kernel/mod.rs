//! The packed microkernel GEMM — the crate's dense compute kernel plane.
//!
//! The per-rank dense products (`X_t·B`, `X_tᵀ·B`, and the k×k core
//! algebra of Algorithm 3) dominate wall time at scale, so they run on a
//! BLIS-style packed kernel instead of a plain blocked triple loop:
//!
//! * **Packing** ([`pack`]) — panels of A and B are copied into
//!   contiguous, microkernel-ordered buffers (`MR×KC` micro-panels of A,
//!   `KC×NR` micro-panels of B). Every transpose variant is just a
//!   different read [`View`] during packing, and a 16-bit operand
//!   ([`HalfMat`]) widens to f32 on the same pass — so all transpose and
//!   precision variants share one inner loop, and transposes are never
//!   materialized.
//! * **SIMD register tiling** ([`micro`], [`dispatch`]) — the microkernel
//!   holds an `MR×NR` tile of C in vector registers across the whole `KC`
//!   depth. The widest kernel the host supports (AVX-512F, AVX2+FMA,
//!   NEON, or the portable scalar reference) is selected once at startup;
//!   all f32 variants produce **bit-identical** results (see [`micro`]).
//! * **Tunable blocking** ([`tune`]) — the MC/KC/NC loop blocking is
//!   runtime-adjustable; `drescal tune` sweeps the grid on the local
//!   machine and persists the winner to a JSON profile that is
//!   auto-loaded next to the bench baseline.
//! * **Reusable scratch** — pack buffers live in per-thread scratch
//!   (`thread_local`), sized once and reused by every subsequent call on
//!   that thread; [`pack_resize_count`] counts this thread's resizes so
//!   tests can assert the steady state performs no pack allocations.
//! * **Threading** — macro-panels of C rows go to scoped worker threads
//!   above [`PAR_THRESHOLD`] fused multiply-adds; each worker packs into
//!   its own scratch.
//!
//! [`gram_into`] is the symmetric special case `AᵀA`: block rows of the
//! upper triangle run through the same packed SIMD core, and the
//! strictly-lower blocks are whole-tile mirrors of kernel-computed
//! values — no scalar accumulation path remains.
//!
//! The previous unpacked kernel survives as
//! [`super::dense::gemm_legacy`] so `drescal bench` can track the
//! packed-vs-legacy gap and parity tests have a second implementation.

pub mod dispatch;
mod micro;
mod pack;
pub mod tune;

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicUsize, Ordering};

use self::dispatch::KernelDesc;
use self::pack::{pack_a, pack_b};
use super::dense::{num_threads, Mat};
use super::half::HalfMat;

/// Largest register-tile height any variant uses.
pub const MR_MAX: usize = 8;
/// Largest register-tile width any variant uses (AVX-512 is 8×16).
pub const NR_MAX: usize = 16;
/// Default rows of A packed per L2-resident macro-panel.
pub const MC_DEFAULT: usize = 64;
/// Default shared inner (depth) blocking.
pub const KC_DEFAULT: usize = 256;
/// Default columns of B packed per macro-panel.
pub const NC_DEFAULT: usize = 1024;

/// Work threshold (fused multiply-adds) below which GEMM stays serial.
const PAR_THRESHOLD: usize = 1 << 20;

/// C-block side for the symmetric gram tiling.
const GRAM_TB: usize = 64;

// Runtime blocking parameters, adjustable by `drescal tune` (and the
// auto-loaded tune profile). Read once per serial-core invocation, so a
// concurrent update never tears a single GEMM.
static BLOCK_MC: AtomicUsize = AtomicUsize::new(MC_DEFAULT);
static BLOCK_KC: AtomicUsize = AtomicUsize::new(KC_DEFAULT);
static BLOCK_NC: AtomicUsize = AtomicUsize::new(NC_DEFAULT);

/// Current (MC, KC, NC) loop blocking.
pub fn blocking() -> (usize, usize, usize) {
    (
        BLOCK_MC.load(Ordering::Relaxed),
        BLOCK_KC.load(Ordering::Relaxed),
        BLOCK_NC.load(Ordering::Relaxed),
    )
}

/// Override the loop blocking (values are clamped to at least one
/// register tile). Takes effect on the next GEMM call.
pub fn set_blocking(mc: usize, kc: usize, nc: usize) {
    BLOCK_MC.store(mc.max(MR_MAX), Ordering::Relaxed);
    BLOCK_KC.store(kc.max(1), Ordering::Relaxed);
    BLOCK_NC.store(nc.max(NR_MAX), Ordering::Relaxed);
}

/// The compiled-in default blocking.
pub fn default_blocking() -> (usize, usize, usize) {
    (MC_DEFAULT, KC_DEFAULT, NC_DEFAULT)
}

/// The packed element source a [`View`] reads through: f32, or a 16-bit
/// storage format widened on access.
#[derive(Clone, Copy)]
pub(crate) enum ViewData<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
    Bf16(&'a [u16]),
}

/// A read-only strided view of a row-major buffer: element `(r, c)` is
/// `data[r*rs + c*cs]`. A transposed operand is the same buffer with the
/// strides swapped — packing through a view makes all transpose variants
/// share the packed inner loop, and the half-precision variants widen
/// here, on pack, so the microkernel only ever sees f32.
#[derive(Clone, Copy)]
pub(crate) struct View<'a> {
    data: ViewData<'a>,
    rs: usize,
    cs: usize,
}

impl<'a> View<'a> {
    pub(crate) fn f32(data: &'a [f32], rs: usize, cs: usize) -> View<'a> {
        View { data: ViewData::F32(data), rs, cs }
    }

    /// View a half matrix's raw payload with explicit strides.
    pub(crate) fn half(m: &'a HalfMat, rs: usize, cs: usize) -> View<'a> {
        use super::half::DType;
        let data = match m.dtype() {
            DType::F16 => ViewData::F16(m.as_u16_slice()),
            DType::Bf16 => ViewData::Bf16(m.as_u16_slice()),
            DType::F32 => unreachable!("HalfMat is never f32"),
        };
        View { data, rs, cs }
    }

    #[inline(always)]
    pub(crate) fn at(&self, r: usize, c: usize) -> f32 {
        let idx = r * self.rs + c * self.cs;
        match self.data {
            ViewData::F32(d) => d[idx],
            ViewData::F16(d) => super::half::f16_to_f32(d[idx]),
            ViewData::Bf16(d) => super::half::bf16_to_f32(d[idx]),
        }
    }

    /// The sub-view starting at row `r0` (same strides).
    fn from_row(&self, r0: usize) -> View<'a> {
        let skip = r0 * self.rs;
        let data = match self.data {
            ViewData::F32(d) => ViewData::F32(&d[skip..]),
            ViewData::F16(d) => ViewData::F16(&d[skip..]),
            ViewData::Bf16(d) => ViewData::Bf16(&d[skip..]),
        };
        View { data, rs: self.rs, cs: self.cs }
    }
}

/// Reusable per-thread pack scratch. Persistent threads (the engine's
/// rank workers) size it on first use and never allocate again; scoped
/// GEMM worker threads get a fresh one per spawn, which is noise next to
/// the spawn itself.
struct PackScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<PackScratch> =
        const { RefCell::new(PackScratch { a: Vec::new(), b: Vec::new() }) };
    static PACK_RESIZES: Cell<usize> = const { Cell::new(0) };
}

/// How many times **this thread** has grown its pack scratch. Stable
/// across warm calls — tests assert the steady-state hot path performs
/// no pack allocations.
pub fn pack_resize_count() -> usize {
    PACK_RESIZES.with(|c| c.get())
}

#[inline]
fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

// ---------------------------------------------------------------------------
// Public entry points: four transpose variants × {f32, half} + gram
// ---------------------------------------------------------------------------

/// `C (+)= A · B` with A `m×k`, B `k×n`. When `accumulate` is false, C is
/// overwritten.
pub fn gemm_nn_into(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    gemm_nn_into_with(dispatch::active(), a, b, c, accumulate);
}

/// [`gemm_nn_into`] on an explicit microkernel variant (parity tests and
/// the autotuner; production paths use the dispatched kernel).
pub fn gemm_nn_into_with(kern: &'static KernelDesc, a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    assert_eq!(c.rows(), a.rows(), "gemm out rows");
    assert_eq!(c.cols(), b.cols(), "gemm out cols");
    if !accumulate {
        c.clear();
    }
    let (m, kdim, n) = (a.rows(), a.cols(), b.cols());
    let av = View::f32(a.as_slice(), a.cols(), 1);
    let bv = View::f32(b.as_slice(), b.cols(), 1);
    gemm_threaded(kern, m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = Aᵀ · B` with A stored `m×k`, B `m×n` (C is `k×n`). Aᵀ is never
/// materialized: packing reads A through the transposed view.
pub fn gemm_tn_into(a: &Mat, b: &Mat, c: &mut Mat) {
    gemm_tn_into_with(dispatch::active(), a, b, c);
}

/// [`gemm_tn_into`] on an explicit microkernel variant.
pub fn gemm_tn_into_with(kern: &'static KernelDesc, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows(), b.rows(), "t_matmul inner dim");
    assert_eq!(c.rows(), a.cols(), "t_matmul out rows");
    assert_eq!(c.cols(), b.cols(), "t_matmul out cols");
    c.clear();
    let (m, kdim, n) = (a.cols(), a.rows(), b.cols());
    let av = View::f32(a.as_slice(), 1, a.cols());
    let bv = View::f32(b.as_slice(), b.cols(), 1);
    gemm_threaded(kern, m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = A · Bᵀ` with A `m×k`, B stored `n×k` (C is `m×n`).
pub fn gemm_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    gemm_nt_into_with(dispatch::active(), a, b, c);
}

/// [`gemm_nt_into`] on an explicit microkernel variant.
pub fn gemm_nt_into_with(kern: &'static KernelDesc, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.cols(), "matmul_t inner dim");
    assert_eq!(c.rows(), a.rows(), "matmul_t out rows");
    assert_eq!(c.cols(), b.rows(), "matmul_t out cols");
    c.clear();
    let (m, kdim, n) = (a.rows(), a.cols(), b.rows());
    let av = View::f32(a.as_slice(), a.cols(), 1);
    let bv = View::f32(b.as_slice(), 1, b.cols());
    gemm_threaded(kern, m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = Aᵀ · Bᵀ` with A stored `k×m`, B stored `n×k` (C is `m×n`).
pub fn gemm_tt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    gemm_tt_into_with(dispatch::active(), a, b, c);
}

/// [`gemm_tt_into`] on an explicit microkernel variant.
pub fn gemm_tt_into_with(kern: &'static KernelDesc, a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows(), b.cols(), "tt inner dim");
    assert_eq!(c.rows(), a.cols(), "tt out rows");
    assert_eq!(c.cols(), b.rows(), "tt out cols");
    c.clear();
    let (m, kdim, n) = (a.cols(), a.rows(), b.rows());
    let av = View::f32(a.as_slice(), 1, a.cols());
    let bv = View::f32(b.as_slice(), 1, b.cols());
    gemm_threaded(kern, m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C (+)= A · B` with A a 16-bit stored `m×k` matrix widened on pack,
/// B f32 `k×n`. Arithmetic is identical to widening A up front and
/// calling [`gemm_nn_into`] — bit for bit — without the widened copy.
pub fn gemm_nn_half_into(a: &HalfMat, b: &Mat, c: &mut Mat, accumulate: bool) {
    gemm_nn_half_into_with(dispatch::active(), a, b, c, accumulate);
}

/// [`gemm_nn_half_into`] on an explicit microkernel variant.
pub fn gemm_nn_half_into_with(
    kern: &'static KernelDesc,
    a: &HalfMat,
    b: &Mat,
    c: &mut Mat,
    accumulate: bool,
) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    assert_eq!(c.rows(), a.rows(), "gemm out rows");
    assert_eq!(c.cols(), b.cols(), "gemm out cols");
    if !accumulate {
        c.clear();
    }
    let (m, kdim, n) = (a.rows(), a.cols(), b.cols());
    let av = View::half(a, a.cols(), 1);
    let bv = View::f32(b.as_slice(), b.cols(), 1);
    gemm_threaded(kern, m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = Aᵀ · B` with A a 16-bit stored `m×k` matrix widened on pack,
/// B f32 `m×n` (C is `k×n`).
pub fn gemm_tn_half_into(a: &HalfMat, b: &Mat, c: &mut Mat) {
    gemm_tn_half_into_with(dispatch::active(), a, b, c);
}

/// [`gemm_tn_half_into`] on an explicit microkernel variant.
pub fn gemm_tn_half_into_with(kern: &'static KernelDesc, a: &HalfMat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows(), b.rows(), "t_matmul inner dim");
    assert_eq!(c.rows(), a.cols(), "t_matmul out rows");
    assert_eq!(c.cols(), b.cols(), "t_matmul out cols");
    c.clear();
    let (m, kdim, n) = (a.cols(), a.rows(), b.cols());
    let av = View::half(a, 1, a.cols());
    let bv = View::f32(b.as_slice(), b.cols(), 1);
    gemm_threaded(kern, m, kdim, n, av, bv, c.as_mut_slice());
}

/// Symmetric gram `C = AᵀA` for A `m×k` (C is `k×k`).
///
/// Block rows of the upper triangle (diagonal block plus everything to
/// its right) run through the packed SIMD core — the same microkernel
/// as every other GEMM — and the strictly-lower blocks are whole-tile
/// mirrors of those kernel-computed values. The result is exactly
/// symmetric: mirrored blocks trivially, and within a diagonal block
/// because `(p,q)` and `(q,p)` accumulate bitwise-commuted FMA chains.
/// Steady-state calls perform no allocations (the per-depth-stripe
/// partial buffers of the old scalar reduction are gone; see
/// [`pack_resize_count`]).
pub fn gram_into(a: &Mat, c: &mut Mat) {
    let (m, k) = a.shape();
    assert_eq!(c.shape(), (k, k), "gram out shape");
    c.clear();
    if m == 0 || k == 0 {
        return;
    }
    let kern = dispatch::active();
    let ad = a.as_slice();
    let cd = c.as_mut_slice();
    for pb0 in (0..k).step_by(GRAM_TB) {
        let pb1 = (pb0 + GRAM_TB).min(k);
        // op-A = Aᵀ rows [pb0, pb1) and op-B = A columns [pb0, k), both
        // strided views of the same buffer
        let av = View::f32(&ad[pb0..], 1, k);
        let bv = View::f32(&ad[pb0..], k, 1);
        gemm_serial_packed(kern, pb1 - pb0, m, k - pb0, av, bv, &mut cd[pb0 * k + pb0..], k);
    }
    // mirror whole strictly-upper tiles into the lower triangle
    for pb0 in (0..k).step_by(GRAM_TB) {
        let pb1 = (pb0 + GRAM_TB).min(k);
        for qb0 in ((pb0 + GRAM_TB)..k).step_by(GRAM_TB) {
            let qb1 = (qb0 + GRAM_TB).min(k);
            for q in qb0..qb1 {
                for p in pb0..pb1 {
                    cd[q * k + p] = cd[p * k + q];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Driver: threading over C row macro-panels, then the packed serial core
// ---------------------------------------------------------------------------

/// `C += OpA · OpB` over strided operand views; C is row-major `m×n`
/// (leading dimension n). Callers clear C first unless accumulating.
fn gemm_threaded(kern: &'static KernelDesc, m: usize, kdim: usize, n: usize, a: View, b: View, c: &mut [f32]) {
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    let work = m * kdim * n;
    let nt = num_threads();
    if work < PAR_THRESHOLD || nt == 1 || m < 2 {
        gemm_serial_packed(kern, m, kdim, n, a, b, c, n);
        return;
    }
    let nt = nt.min(m);
    let chunk = m.div_ceil(nt);
    let c_chunks: Vec<&mut [f32]> = c.chunks_mut(chunk * n).collect();
    std::thread::scope(|s| {
        for (t, c_chunk) in c_chunks.into_iter().enumerate() {
            let a_sub = a.from_row(t * chunk);
            s.spawn(move || {
                let rows = c_chunk.len() / n;
                gemm_serial_packed(kern, rows, kdim, n, a_sub, b, c_chunk, n);
            });
        }
    });
}

/// The serial packed core: 5-loop blocking with pack-then-microkernel.
/// `c` starts at the output block's top-left corner and has leading
/// dimension `ldc` (≥ n; the gram path writes sub-blocks of a wider C).
#[allow(clippy::too_many_arguments)]
fn gemm_serial_packed(
    kern: &KernelDesc,
    m: usize,
    kdim: usize,
    n: usize,
    a: View,
    b: View,
    c: &mut [f32],
    ldc: usize,
) {
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    let (mc, kc, nc) = blocking();
    let a_need = round_up(mc.min(m), kern.mr) * kc.min(kdim);
    let b_need = kc.min(kdim) * round_up(nc.min(n), kern.nr);
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let PackScratch { a: abuf, b: bbuf } = &mut *scratch;
        if abuf.len() < a_need {
            abuf.resize(a_need, 0.0);
            PACK_RESIZES.with(|ctr| ctr.set(ctr.get() + 1));
        }
        if bbuf.len() < b_need {
            bbuf.resize(b_need, 0.0);
            PACK_RESIZES.with(|ctr| ctr.set(ctr.get() + 1));
        }
        for jc in (0..n).step_by(nc) {
            let nb = nc.min(n - jc);
            for pc in (0..kdim).step_by(kc) {
                let kb = kc.min(kdim - pc);
                pack_b(b, pc, kb, jc, nb, kern.nr, bbuf);
                for ic in (0..m).step_by(mc) {
                    let mb = mc.min(m - ic);
                    pack_a(a, ic, mb, pc, kb, kern.mr, abuf);
                    macro_kernel(
                        kern,
                        mb,
                        kb,
                        nb,
                        (abuf.as_slice(), bbuf.as_slice()),
                        &mut c[ic * ldc + jc..],
                        ldc,
                    );
                }
            }
        }
    });
}

/// Run the microkernel over every `MR×NR` tile of one packed macro-block.
/// `c` starts at the block's top-left corner of the full C (leading
/// dimension `ldc`).
fn macro_kernel(
    kern: &KernelDesc,
    mb: usize,
    kb: usize,
    nb: usize,
    (apack, bpack): (&[f32], &[f32]),
    c: &mut [f32],
    ldc: usize,
) {
    let (mr, nr) = (kern.mr, kern.nr);
    debug_assert!(mr <= MR_MAX && nr <= NR_MAX);
    for jr in (0..nb).step_by(nr) {
        let nrb = nr.min(nb - jr);
        let bp = &bpack[(jr / nr) * (kb * nr)..][..kb * nr];
        for ir in (0..mb).step_by(mr) {
            let mrb = mr.min(mb - ir);
            let ap = &apack[(ir / mr) * (mr * kb)..][..mr * kb];
            let c_off = ir * ldc + jr;
            if mrb == mr && nrb == nr {
                let ctile = &mut c[c_off..];
                debug_assert!((mr - 1) * ldc + nr <= ctile.len());
                // SAFETY: ap/bp hold kb*mr / kb*nr packed f32; every tile
                // row i spans ctile[i*ldc .. i*ldc + nr], in bounds by the
                // assert above; the dispatcher verified CPU features.
                unsafe { (kern.tile)(kb, ap.as_ptr(), bp.as_ptr(), ctile.as_mut_ptr(), ldc) };
            } else {
                // ragged edge: run the same kernel into a zeroed local
                // tile, then add back only the valid mrb×nrb corner
                let mut tile = [0.0f32; MR_MAX * NR_MAX];
                // SAFETY: as above; the local tile is mr×nr with ldc=nr,
                // and mr*nr ≤ MR_MAX*NR_MAX.
                unsafe { (kern.tile)(kb, ap.as_ptr(), bp.as_ptr(), tile.as_mut_ptr(), nr) };
                for i in 0..mrb {
                    let crow = &mut c[c_off + i * ldc..c_off + i * ldc + nrb];
                    for (cv, &tv) in crow.iter_mut().zip(&tile[i * nr..i * nr + nrb]) {
                        *cv += tv;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    // Naive-reference parity across adversarial shapes, all transpose
    // variants, and every host microkernel lives in
    // rust/tests/kernel_plane.rs (one copy, exercised through the public
    // Backend/kernel API); the tests here cover what only this module
    // can reach — blocking edges, the accumulate contract, the
    // symmetric gram, and the private serial core vs the threaded
    // dispatcher.

    #[test]
    fn empty_dims_are_fine() {
        // k = 0: the product of an m×0 and a 0×n matrix is all zeros
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let mut c = Mat::full(3, 4, 7.0);
        gemm_nn_into(&a, &b, &mut c, false);
        assert_eq!(c.as_slice(), &[0.0; 12][..]);
        // m = 0 / n = 0: empty outputs, no panic
        let mut c = Mat::zeros(0, 4);
        gemm_nn_into(&Mat::zeros(0, 5), &Mat::zeros(5, 4), &mut c, false);
        let mut c = Mat::zeros(3, 0);
        gemm_nn_into(&Mat::zeros(3, 5), &Mat::zeros(5, 0), &mut c, false);
        let mut g = Mat::zeros(0, 0);
        gram_into(&Mat::zeros(4, 0), &mut g);
    }

    #[test]
    fn accumulate_adds_on_top() {
        let a = Mat::eye(5);
        let b = Mat::full(5, 5, 2.0);
        let mut c = Mat::full(5, 5, 1.0);
        gemm_nn_into(&a, &b, &mut c, true);
        assert_eq!(c.as_slice(), &[3.0f32; 25][..]);
    }

    #[test]
    fn gram_matches_tn_and_is_exactly_symmetric() {
        let mut rng = Rng::new(501);
        // shapes straddle the GRAM_TB block size so mirrored off-diagonal
        // blocks are exercised (k = 130 > 2·64)
        for &(m, k) in &[(1, 1), (5, 3), (40, 8), (130, 17), (300, 33), (90, 130)] {
            let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
            let mut g = Mat::zeros(k, k);
            gram_into(&a, &mut g);
            let mut want = Mat::zeros(k, k);
            gemm_tn_into(&a, &a, &mut want);
            assert_close(g.as_slice(), want.as_slice(), 1e-3);
            for p in 0..k {
                for q in 0..k {
                    assert_eq!(g[(p, q)], g[(q, p)], "gram not exactly symmetric");
                }
            }
        }
    }

    #[test]
    fn threaded_path_matches_serial_result() {
        // large enough to cross PAR_THRESHOLD on multi-core hosts; on a
        // single-core host this still exercises the serial packed core
        let mut rng = Rng::new(502);
        let (m, kdim, n) = (190, 85, 110);
        let a = Mat::random_uniform(m, kdim, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(kdim, n, -1.0, 1.0, &mut rng);
        let mut c = Mat::zeros(m, n);
        gemm_nn_into(&a, &b, &mut c, false);
        let mut serial = Mat::zeros(m, n);
        gemm_serial_packed(
            dispatch::active(),
            m,
            kdim,
            n,
            View::f32(a.as_slice(), kdim, 1),
            View::f32(b.as_slice(), n, 1),
            serial.as_mut_slice(),
            n,
        );
        assert_close(c.as_slice(), serial.as_slice(), 1e-4);
    }

    #[test]
    fn half_gemm_is_bitwise_equal_to_widened_f32_gemm() {
        use crate::tensor::half::{DType, HalfMat, HalfTensor3};
        let mut rng = Rng::new(503);
        let (m, kdim, n) = (33, 29, 21);
        let a = Mat::random_uniform(m, kdim, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(kdim, n, -1.0, 1.0, &mut rng);
        for dtype in [DType::F16, DType::Bf16] {
            let ha = HalfMat::from_f32(&a, dtype);
            let widened = ha.to_f32();
            let mut got = Mat::zeros(m, n);
            gemm_nn_half_into(&ha, &b, &mut got, false);
            let mut want = Mat::zeros(m, n);
            gemm_nn_into(&widened, &b, &mut want, false);
            assert_eq!(got.as_slice(), want.as_slice(), "{dtype:?} nn widen-on-pack");
            let mut got_t = Mat::zeros(kdim, n);
            let bt = Mat::random_uniform(m, n, -1.0, 1.0, &mut rng);
            gemm_tn_half_into(&ha, &bt, &mut got_t);
            let mut want_t = Mat::zeros(kdim, n);
            gemm_tn_into(&widened, &bt, &mut want_t);
            assert_eq!(got_t.as_slice(), want_t.as_slice(), "{dtype:?} tn widen-on-pack");
        }
        // keep HalfTensor3 linked into the doc example surface
        let _ = HalfTensor3::from_tensor3(&crate::tensor::Tensor3::zeros(2, 2, 1), DType::F16);
    }

    #[test]
    fn blocking_overrides_round_trip_and_clamp() {
        let saved = blocking();
        set_blocking(96, 128, 512);
        assert_eq!(blocking(), (96, 128, 512));
        set_blocking(1, 0, 1);
        assert_eq!(blocking(), (MR_MAX, 1, NR_MAX));
        // results stay correct under odd blocking
        let mut rng = Rng::new(504);
        let a = Mat::random_uniform(30, 40, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(40, 22, -1.0, 1.0, &mut rng);
        set_blocking(24, 17, 40);
        let got = {
            let mut c = Mat::zeros(30, 22);
            gemm_nn_into(&a, &b, &mut c, false);
            c
        };
        set_blocking(saved.0, saved.1, saved.2);
        let mut want = Mat::zeros(30, 22);
        gemm_nn_into(&a, &b, &mut want, false);
        assert_close(got.as_slice(), want.as_slice(), 1e-4);
        assert_eq!(default_blocking(), (MC_DEFAULT, KC_DEFAULT, NC_DEFAULT));
    }
}
