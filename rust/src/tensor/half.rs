//! Half-precision (f16 / bf16) storage substrate.
//!
//! The paper's exascale footprints are bandwidth-bound, so dense tile
//! shards and factor artifacts can be **stored** at 16 bits per element
//! while all arithmetic stays f32: the kernel plane widens values on
//! pack (see `super::kernel`), so a half-precision operand runs through
//! the exact same f32 microkernel accumulators as an f32 one. This file
//! provides the dependency-free bit conversions (round-to-nearest-even,
//! matching hardware F16C/BF16 convert semantics), a [`HalfMat`] that
//! mirrors [`Mat`]'s owned/shared (mmap copy-on-write) storage split,
//! and a [`HalfTensor3`] of relation slices.
//!
//! [`Mat`]: super::dense::Mat

use std::sync::Arc;

use super::dense::Mat;
use super::tensor3::Tensor3;

/// Element type of a stored dense payload. `F32` is the default and the
/// only arithmetic precision; `F16`/`Bf16` are storage-only formats that
/// halve shard bytes and memory bandwidth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F16,
    Bf16,
}

impl DType {
    /// Canonical lowercase name (used in manifests, CLI flags, headers).
    pub fn as_str(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::Bf16 => "bf16",
        }
    }

    /// Parse a CLI/manifest dtype name.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f16" => Some(DType::F16),
            "bf16" => Some(DType::Bf16),
            _ => None,
        }
    }

    /// Stored bytes per element.
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::Bf16 => 2,
        }
    }

    /// Whether this is a 16-bit storage format.
    pub fn is_half(self) -> bool {
        !matches!(self, DType::F32)
    }

    /// Round-trip a value through this storage format (identity for
    /// `F32`) — the value an element takes after being stored and read
    /// back.
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            DType::F32 => x,
            DType::F16 => f16_to_f32(f32_to_f16(x)),
            DType::Bf16 => bf16_to_f32(f32_to_bf16(x)),
        }
    }

    /// Narrow an f32 to this format's 16-bit pattern. Panics for `F32`,
    /// which has no 16-bit pattern.
    pub fn narrow(self, x: f32) -> u16 {
        match self {
            DType::F32 => unreachable!("f32 is not a 16-bit storage format"),
            DType::F16 => f32_to_f16(x),
            DType::Bf16 => f32_to_bf16(x),
        }
    }

    /// Widen this format's 16-bit pattern to f32. Panics for `F32`.
    pub fn widen(self, h: u16) -> f32 {
        match self {
            DType::F32 => unreachable!("f32 is not a 16-bit storage format"),
            DType::F16 => f16_to_f32(h),
            DType::Bf16 => bf16_to_f32(h),
        }
    }
}

// ---------------------------------------------------------------------------
// Bit conversions (round-to-nearest-even, software — no intrinsics, so
// results are identical on every host)
// ---------------------------------------------------------------------------

/// Convert f32 → IEEE 754 binary16 bits with round-to-nearest-even.
/// Overflow rounds to ±inf, underflow to ±0, NaN to a canonical qNaN.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs > 0x7f80_0000 {
        return sign | 0x7e00; // NaN → canonical quiet NaN
    }
    if abs >= 0x4780_0000 {
        return sign | 0x7c00; // ±inf, and finite values ≥ 2^16 overflow
    }
    if abs >= 0x3880_0000 {
        // normal half range (exponent ≥ −14): rebias 127→15, then RNE on
        // the 13 mantissa bits dropped by the 23→10 narrowing
        let rebiased = abs - 0x3800_0000;
        let rounded = rebiased + 0x0fff + ((rebiased >> 13) & 1);
        return sign | (rounded >> 13) as u16;
    }
    // subnormal half (|x| < 2^−14): shift the mantissa (with its hidden
    // bit) into place and round; exp < 102 means |x| < 2^−25 → ±0
    let exp = (abs >> 23) as i32;
    if exp < 102 {
        return sign;
    }
    let mant = (abs & 0x007f_ffff) | 0x0080_0000;
    let shift = (126 - exp) as u32;
    let half = (mant >> shift) as u16;
    let round_bit = 1u32 << (shift - 1);
    if (mant & round_bit) != 0 && ((mant & (round_bit - 1)) != 0 || (half & 1) != 0) {
        return sign | (half + 1); // carry into the exponent is correct here
    }
    sign | half
}

/// Convert IEEE 754 binary16 bits → f32 (exact — every f16 value is
/// representable in f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let mant = (h & 0x03ff) as u32;
    let bits = match exp {
        0 => {
            if mant == 0 {
                sign // ±0
            } else {
                // subnormal: renormalize into the f32 exponent range
                let mut e = 113u32; // −14 rebias (127 − 15 + 1)
                let mut m = mant;
                while m & 0x0400 == 0 {
                    m <<= 1;
                    e -= 1;
                }
                sign | (e << 23) | ((m & 0x03ff) << 13)
            }
        }
        0x1f => sign | 0x7f80_0000 | (mant << 13), // ±inf / NaN
        _ => sign | ((exp as u32 + 112) << 23) | (mant << 13),
    };
    f32::from_bits(bits)
}

/// Convert f32 → bfloat16 bits with round-to-nearest-even (bf16 is the
/// top 16 bits of an f32, so this is rounding truncation).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if bits & 0x7fff_ffff > 0x7f80_0000 {
        return ((bits >> 16) as u16) | 0x0040; // NaN stays NaN after truncation
    }
    let rounded = bits.wrapping_add(0x7fff + ((bits >> 16) & 1));
    (rounded >> 16) as u16
}

/// Convert bfloat16 bits → f32 (exact: shift back into the top half).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------------
// HalfMat: a 16-bit stored matrix with owned / shared (mmap) storage
// ---------------------------------------------------------------------------

/// Read-only storage a half matrix can window into without copying — in
/// practice the memory-mapped `u16` payload of a half-precision dense
/// shard (see `crate::store`).
pub type SharedHalfBuf = Arc<dyn AsRef<[u16]> + Send + Sync>;

#[derive(Clone)]
enum HalfBuf {
    Owned(Vec<u16>),
    Shared { src: SharedHalfBuf, off: usize, len: usize },
}

impl std::ops::Deref for HalfBuf {
    type Target = [u16];
    #[inline]
    fn deref(&self) -> &[u16] {
        match self {
            HalfBuf::Owned(v) => v,
            HalfBuf::Shared { src, off, len } => {
                let s: &[u16] = (**src).as_ref();
                &s[*off..*off + *len]
            }
        }
    }
}

/// Dense row-major matrix stored at 16 bits per element (`F16` or
/// `Bf16`). Reads widen to f32; there is no half arithmetic — products
/// go through the kernel plane's widen-on-pack path. Like [`Mat`], a
/// shard-backed instance stays a zero-copy window until first mutation.
#[derive(Clone)]
pub struct HalfMat {
    rows: usize,
    cols: usize,
    dtype: DType,
    data: HalfBuf,
}

impl HalfMat {
    /// Quantize an f32 matrix into 16-bit storage.
    pub fn from_f32(m: &Mat, dtype: DType) -> HalfMat {
        assert!(dtype.is_half(), "HalfMat dtype must be f16 or bf16");
        let data = m.as_slice().iter().map(|&x| dtype.narrow(x)).collect();
        HalfMat { rows: m.rows(), cols: m.cols(), dtype, data: HalfBuf::Owned(data) }
    }

    /// Build from an existing row-major 16-bit buffer.
    pub fn from_raw(rows: usize, cols: usize, dtype: DType, data: Vec<u16>) -> HalfMat {
        assert!(dtype.is_half(), "HalfMat dtype must be f16 or bf16");
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        HalfMat { rows, cols, dtype, data: HalfBuf::Owned(data) }
    }

    /// Zero-copy window of `rows·cols` u16s into a shared buffer starting
    /// at element `offset` (e.g. a memory-mapped shard payload).
    pub fn from_shared(
        rows: usize,
        cols: usize,
        dtype: DType,
        src: SharedHalfBuf,
        offset: usize,
    ) -> HalfMat {
        assert!(dtype.is_half(), "HalfMat dtype must be f16 or bf16");
        let total = (*src).as_ref().len();
        assert!(offset + rows * cols <= total, "shared buffer window out of range");
        HalfMat { rows, cols, dtype, data: HalfBuf::Shared { src, off: offset, len: rows * cols } }
    }

    /// Whether this matrix still reads from shared (memory-mapped)
    /// storage.
    pub fn is_shared(&self) -> bool {
        matches!(self.data, HalfBuf::Shared { .. })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// The raw 16-bit payload, row-major.
    #[inline]
    pub fn as_u16_slice(&self) -> &[u16] {
        &self.data
    }

    /// Widened element read.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.dtype.widen(self.data[i * self.cols + j])
    }

    /// Widen the whole matrix into f32.
    pub fn to_f32(&self) -> Mat {
        let dtype = self.dtype;
        let data: Vec<f32> = self.data.iter().map(|&h| dtype.widen(h)).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    /// Sum of squared (widened) entries, accumulated in f64.
    pub fn sum_sq(&self) -> f64 {
        let dtype = self.dtype;
        self.data
            .iter()
            .map(|&h| {
                let v = dtype.widen(h) as f64;
                v * v
            })
            .sum()
    }

    /// Apply `f` to every (widened) element and store the narrowed
    /// result — copies a shared window into owned storage first.
    pub fn map_in_place(&mut self, mut f: impl FnMut(f32) -> f32) {
        let dtype = self.dtype;
        if let HalfBuf::Shared { .. } = self.data {
            self.data = HalfBuf::Owned(self.data.to_vec());
        }
        match &mut self.data {
            HalfBuf::Owned(v) => {
                for h in v.iter_mut() {
                    *h = dtype.narrow(f(dtype.widen(*h)));
                }
            }
            HalfBuf::Shared { .. } => unreachable!("shared storage was just copied"),
        }
    }
}

impl std::fmt::Debug for HalfMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "HalfMat {{ {}x{} {} {} }}",
            self.rows,
            self.cols,
            self.dtype.as_str(),
            if self.is_shared() { "shared" } else { "owned" }
        )
    }
}

/// Third-order tensor of 16-bit stored relation slices — the
/// half-precision analogue of [`Tensor3`].
#[derive(Clone, Debug)]
pub struct HalfTensor3 {
    n1: usize,
    n2: usize,
    slices: Vec<HalfMat>,
}

impl HalfTensor3 {
    /// Quantize an f32 tensor into 16-bit storage.
    pub fn from_tensor3(t: &Tensor3, dtype: DType) -> HalfTensor3 {
        let slices = t.slices().iter().map(|s| HalfMat::from_f32(s, dtype)).collect();
        HalfTensor3 { n1: t.n1(), n2: t.n2(), slices }
    }

    /// Build from existing slices (all must share shape and dtype).
    pub fn from_slices(slices: Vec<HalfMat>) -> HalfTensor3 {
        assert!(!slices.is_empty(), "tensor needs at least one slice");
        let (n1, n2) = slices[0].shape();
        let dtype = slices[0].dtype();
        assert!(
            slices.iter().all(|s| s.shape() == (n1, n2) && s.dtype() == dtype),
            "ragged or mixed-dtype slices"
        );
        HalfTensor3 { n1, n2, slices }
    }

    #[inline]
    pub fn n1(&self) -> usize {
        self.n1
    }

    #[inline]
    pub fn n2(&self) -> usize {
        self.n2
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.slices.len()
    }

    #[inline]
    pub fn dtype(&self) -> DType {
        self.slices[0].dtype()
    }

    #[inline]
    pub fn slice(&self, t: usize) -> &HalfMat {
        &self.slices[t]
    }

    #[inline]
    pub fn slice_mut(&mut self, t: usize) -> &mut HalfMat {
        &mut self.slices[t]
    }

    pub fn slices(&self) -> &[HalfMat] {
        &self.slices
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.n1 * self.n2 * self.m()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Widen the whole tensor into f32.
    pub fn to_f32(&self) -> Tensor3 {
        Tensor3::from_slices(self.slices.iter().map(|s| s.to_f32()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn f16_roundtrip_is_exact_for_all_finite_patterns() {
        // every finite f16 bit pattern widens to an exactly-representable
        // f32 and narrows back to the identical pattern
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/NaN handled below
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "pattern {h:#06x}");
        }
        assert_eq!(f32_to_f16(f16_to_f32(0x7c00)), 0x7c00, "+inf");
        assert_eq!(f32_to_f16(f16_to_f32(0xfc00)), 0xfc00, "-inf");
        assert!(f16_to_f32(0x7e00).is_nan());
        assert_eq!(f32_to_f16(f32::NAN) & 0x7c00, 0x7c00);
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16 (1 + 2^-10):
        // ties go to the even mantissa (1.0)
        assert_eq!(f32_to_f16(1.0 + 0.000_488_281_25), 0x3c00);
        // anything above the tie rounds up
        assert_eq!(f32_to_f16(1.0 + 0.000_489), 0x3c01);
        // overflow → inf, underflow → 0
        assert_eq!(f32_to_f16(1e6), 0x7c00);
        assert_eq!(f32_to_f16(-1e6), 0xfc00);
        assert_eq!(f32_to_f16(1e-9), 0x0000);
        // largest finite half
        assert_eq!(f16_to_f32(0x7bff), 65504.0);
        assert_eq!(f32_to_f16(65504.0), 0x7bff);
        // smallest subnormal half
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
    }

    #[test]
    fn bf16_roundtrip_and_rounding() {
        for h in 0u16..=0xffff {
            let exp = (h >> 7) & 0xff;
            if exp == 0xff {
                continue;
            }
            assert_eq!(f32_to_bf16(bf16_to_f32(h)), h, "pattern {h:#06x}");
        }
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7f80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xff80);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // 1 + 2^-9 ties between 1.0 and 1 + 2^-8 → even (1.0)
        assert_eq!(f32_to_bf16(1.0 + 0.001_953_125), 0x3f80);
        assert_eq!(f32_to_bf16(1.0 + 0.001_96), 0x3f81);
    }

    #[test]
    fn quantization_error_is_bounded() {
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            let x = rng.uniform_range(-100.0, 100.0);
            let f16e = (DType::F16.quantize(x) - x).abs() / x.abs().max(1e-6);
            let bf16e = (DType::Bf16.quantize(x) - x).abs() / x.abs().max(1e-6);
            assert!(f16e <= 0.0005, "f16 rel err {f16e} at {x}");
            assert!(bf16e <= 0.004, "bf16 rel err {bf16e} at {x}");
        }
    }

    #[test]
    fn half_mat_widens_and_windows() {
        let mut rng = Rng::new(12);
        let m = Mat::random_uniform(5, 7, -2.0, 2.0, &mut rng);
        for dtype in [DType::F16, DType::Bf16] {
            let h = HalfMat::from_f32(&m, dtype);
            assert_eq!(h.shape(), (5, 7));
            assert!(!h.is_shared());
            let w = h.to_f32();
            for i in 0..5 {
                for j in 0..7 {
                    assert_eq!(w[(i, j)], dtype.quantize(m[(i, j)]));
                    assert_eq!(h.at(i, j), w[(i, j)]);
                }
            }
        }
        // shared window: zero-copy reads, map_in_place copies on write
        let backing: Vec<u16> = (0..12).map(|i| f32_to_f16(i as f32)).collect();
        let src: SharedHalfBuf = Arc::new(backing);
        let mut h = HalfMat::from_shared(3, 4, DType::F16, Arc::clone(&src), 0);
        assert!(h.is_shared());
        assert_eq!(h.at(1, 2), 6.0);
        h.map_in_place(|v| v + 1.0);
        assert!(!h.is_shared());
        assert_eq!(h.at(1, 2), 7.0);
        let other: &[u16] = (*src).as_ref();
        assert_eq!(other[6], f32_to_f16(6.0), "sibling window untouched");
    }

    #[test]
    fn half_tensor_round_trips() {
        let mut rng = Rng::new(13);
        let t = Tensor3::random_uniform(4, 3, 2, 0.0, 1.0, &mut rng);
        let ht = HalfTensor3::from_tensor3(&t, DType::Bf16);
        assert_eq!((ht.n1(), ht.n2(), ht.m()), (4, 3, 2));
        assert_eq!(ht.dtype(), DType::Bf16);
        let back = ht.to_f32();
        for s in 0..2 {
            for i in 0..4 {
                for j in 0..3 {
                    assert_eq!(back.slice(s)[(i, j)], DType::Bf16.quantize(t.slice(s)[(i, j)]));
                }
            }
        }
    }
}
