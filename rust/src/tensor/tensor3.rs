//! Third-order tensor stored as relation slices.
//!
//! pyDRESCALk's Algorithm 3 walks the tensor slice-by-slice along the
//! relation axis (m), so `Tensor3` stores `m` dense `n1×n2` matrices. This
//! matches the paper's "slice the tensor into matrices and perform matrix
//! operations" design (§4.1).

use super::dense::Mat;
use crate::rng::Rng;

/// Dense third-order tensor `n1 × n2 × m` stored as `m` frontal slices.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor3 {
    n1: usize,
    n2: usize,
    slices: Vec<Mat>,
}

impl Tensor3 {
    /// Zero tensor.
    pub fn zeros(n1: usize, n2: usize, m: usize) -> Self {
        Tensor3 { n1, n2, slices: (0..m).map(|_| Mat::zeros(n1, n2)).collect() }
    }

    /// Build from existing slices (all must share a shape).
    pub fn from_slices(slices: Vec<Mat>) -> Self {
        assert!(!slices.is_empty(), "tensor needs at least one slice");
        let (n1, n2) = slices[0].shape();
        assert!(slices.iter().all(|s| s.shape() == (n1, n2)), "ragged slices");
        Tensor3 { n1, n2, slices }
    }

    /// Uniform random tensor in [lo, hi).
    pub fn random_uniform(n1: usize, n2: usize, m: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        Tensor3 {
            n1,
            n2,
            slices: (0..m).map(|_| Mat::random_uniform(n1, n2, lo, hi, rng)).collect(),
        }
    }

    #[inline]
    pub fn n1(&self) -> usize {
        self.n1
    }

    #[inline]
    pub fn n2(&self) -> usize {
        self.n2
    }

    /// Number of relation slices.
    #[inline]
    pub fn m(&self) -> usize {
        self.slices.len()
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.n1, self.n2, self.m())
    }

    #[inline]
    pub fn slice(&self, t: usize) -> &Mat {
        &self.slices[t]
    }

    #[inline]
    pub fn slice_mut(&mut self, t: usize) -> &mut Mat {
        &mut self.slices[t]
    }

    pub fn slices(&self) -> &[Mat] {
        &self.slices
    }

    /// Frobenius norm over all slices.
    pub fn norm_fro(&self) -> f32 {
        let ss: f64 = self
            .slices
            .iter()
            .map(|s| {
                let n = s.norm_fro() as f64;
                n * n
            })
            .sum();
        ss.sqrt() as f32
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.n1 * self.n2 * self.m()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Extract the sub-tensor with rows `r0..r1` and cols `c0..c1` of every
    /// slice — the local tile a virtual rank owns in the 2D grid layout.
    pub fn tile(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Tensor3 {
        assert!(r1 <= self.n1 && c1 <= self.n2 && r0 <= r1 && c0 <= c1);
        let slices = self
            .slices
            .iter()
            .map(|s| {
                Mat::from_fn(r1 - r0, c1 - c0, |i, j| s[(r0 + i, c0 + j)])
            })
            .collect();
        Tensor3 { n1: r1 - r0, n2: c1 - c0, slices }
    }

    /// Relative reconstruction error `‖X − A R Aᵀ‖_F / ‖X‖_F`.
    pub fn rel_error(&self, a: &Mat, r: &Tensor3) -> f32 {
        assert_eq!(r.m(), self.m());
        let mut num = 0.0f64;
        for t in 0..self.m() {
            let ar = a.matmul(r.slice(t));
            let rec = ar.matmul_t(a); // A R_t Aᵀ
            let mut diff = self.slice(t).clone();
            diff.sub_assign(&rec);
            let d = diff.norm_fro() as f64;
            num += d * d;
        }
        (num.sqrt() / self.norm_fro() as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_len() {
        let t = Tensor3::zeros(4, 5, 3);
        assert_eq!(t.shape(), (4, 5, 3));
        assert_eq!(t.len(), 60);
    }

    #[test]
    fn tile_extraction() {
        let mut t = Tensor3::zeros(4, 4, 2);
        t.slice_mut(1)[(2, 3)] = 7.0;
        let tile = t.tile(2, 4, 2, 4);
        assert_eq!(tile.shape(), (2, 2, 2));
        assert_eq!(tile.slice(1)[(0, 1)], 7.0);
    }

    #[test]
    fn tiles_partition_norm() {
        let mut rng = Rng::new(8);
        let t = Tensor3::random_uniform(6, 6, 2, 0.0, 1.0, &mut rng);
        let mut ss = 0.0f64;
        for (r0, r1) in [(0, 3), (3, 6)] {
            for (c0, c1) in [(0, 3), (3, 6)] {
                let n = t.tile(r0, r1, c0, c1).norm_fro() as f64;
                ss += n * n;
            }
        }
        assert!((ss.sqrt() as f32 - t.norm_fro()).abs() < 1e-4);
    }

    #[test]
    fn rel_error_zero_for_exact_factorization() {
        let mut rng = Rng::new(9);
        let a = Mat::random_uniform(8, 3, 0.0, 1.0, &mut rng);
        let r = Tensor3::random_uniform(3, 3, 2, 0.0, 1.0, &mut rng);
        // X = A R Aᵀ exactly
        let slices = (0..2)
            .map(|t| a.matmul(r.slice(t)).matmul_t(&a))
            .collect();
        let x = Tensor3::from_slices(slices);
        assert!(x.rel_error(&a, &r) < 1e-5);
    }

    #[test]
    fn rel_error_one_for_zero_factors() {
        let mut rng = Rng::new(10);
        let x = Tensor3::random_uniform(6, 6, 2, 0.1, 1.0, &mut rng);
        let a = Mat::zeros(6, 2);
        let r = Tensor3::zeros(2, 2, 2);
        assert!((x.rel_error(&a, &r) - 1.0).abs() < 1e-6);
    }
}
