//! The packed microkernel GEMM — the crate's dense compute kernel plane.
//!
//! The per-rank dense products (`X_t·B`, `X_tᵀ·B`, and the k×k core
//! algebra of Algorithm 3) dominate wall time at scale, so they run on a
//! BLIS-style packed kernel instead of a plain blocked triple loop:
//!
//! * **Packing** — before multiplying, panels of A and B are copied into
//!   contiguous, microkernel-ordered buffers (`MR×KC` micro-panels of A,
//!   `KC×NR` micro-panels of B). Every transpose variant is just a
//!   different read [`View`] during packing, so the four entry points
//!   ([`gemm_nn_into`], [`gemm_tn_into`], [`gemm_nt_into`],
//!   [`gemm_tt_into`]) share one inner loop and transposes are never
//!   materialized.
//! * **Register tiling** — the microkernel holds an `MR×NR` tile of C in
//!   registers across the whole `KC` depth, so C traffic drops from one
//!   read+write per multiply (the old axpy kernel) to one per `KC`
//!   multiplies. Ragged edges run the same kernel on zero-padded packed
//!   panels and write back only the valid `mr×nr` corner.
//! * **Reusable scratch** — pack buffers live in per-thread scratch
//!   (`thread_local`), sized once and reused by every subsequent call on
//!   that thread, so steady-state GEMMs on the persistent rank threads
//!   perform no pack allocations. Iteration-level temporaries are owned
//!   by the per-rank [`crate::backend::Workspace`] arena; together the
//!   two make the training hot loop allocation-free in steady state.
//! * **Threading** — macro-panels of C rows go to scoped worker threads
//!   above the same work threshold as before ([`PAR_THRESHOLD`] fused
//!   multiply-adds); each worker packs into its own scratch.
//!
//! [`gram_into`] is the symmetric special case `AᵀA`: it accumulates only
//! the upper triangle (half the multiplies) and mirrors the rest.
//!
//! The previous unpacked kernel survives as
//! [`super::dense::gemm_legacy`] so `drescal bench` can track the
//! packed-vs-legacy gap and parity tests have a second implementation.

use std::cell::RefCell;

use super::dense::{num_threads, Mat};

/// Microkernel tile height (rows of C held in registers).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C held in registers).
pub const NR: usize = 8;
/// Rows of A packed per L2-resident macro-panel (multiple of MR).
pub const MC: usize = 64;
/// Shared inner (depth) blocking.
pub const KC: usize = 256;
/// Columns of B packed per macro-panel (multiple of NR).
pub const NC: usize = 1024;

/// Work threshold (fused multiply-adds) below which GEMM stays serial.
const PAR_THRESHOLD: usize = 1 << 20;

/// A read-only strided view of a row-major buffer: element `(r, c)` is
/// `data[r*rs + c*cs]`. A transposed operand is the same buffer with the
/// strides swapped — packing through a view makes all transpose variants
/// share the packed inner loop.
#[derive(Clone, Copy)]
struct View<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
}

impl<'a> View<'a> {
    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c * self.cs]
    }

    /// The sub-view starting at row `r0` (same strides).
    fn from_row(&self, r0: usize) -> View<'a> {
        View { data: &self.data[r0 * self.rs..], rs: self.rs, cs: self.cs }
    }
}

/// Reusable per-thread pack scratch. Persistent threads (the engine's
/// rank workers) size it on first use and never allocate again; scoped
/// GEMM worker threads get a fresh one per spawn, which is noise next to
/// the spawn itself.
struct PackScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<PackScratch> =
        const { RefCell::new(PackScratch { a: Vec::new(), b: Vec::new() }) };
}

#[inline]
fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

// ---------------------------------------------------------------------------
// Public entry points: the four transpose variants + symmetric gram
// ---------------------------------------------------------------------------

/// `C (+)= A · B` with A `m×k`, B `k×n`. When `accumulate` is false, C is
/// overwritten.
pub fn gemm_nn_into(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    assert_eq!(c.rows(), a.rows(), "gemm out rows");
    assert_eq!(c.cols(), b.cols(), "gemm out cols");
    if !accumulate {
        c.clear();
    }
    let (m, kdim, n) = (a.rows(), a.cols(), b.cols());
    let av = View { data: a.as_slice(), rs: a.cols(), cs: 1 };
    let bv = View { data: b.as_slice(), rs: b.cols(), cs: 1 };
    gemm_dispatch(m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = Aᵀ · B` with A stored `m×k`, B `m×n` (C is `k×n`). Aᵀ is never
/// materialized: packing reads A through the transposed view.
pub fn gemm_tn_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows(), b.rows(), "t_matmul inner dim");
    assert_eq!(c.rows(), a.cols(), "t_matmul out rows");
    assert_eq!(c.cols(), b.cols(), "t_matmul out cols");
    c.clear();
    let (m, kdim, n) = (a.cols(), a.rows(), b.cols());
    let av = View { data: a.as_slice(), rs: 1, cs: a.cols() };
    let bv = View { data: b.as_slice(), rs: b.cols(), cs: 1 };
    gemm_dispatch(m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = A · Bᵀ` with A `m×k`, B stored `n×k` (C is `m×n`).
pub fn gemm_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.cols(), "matmul_t inner dim");
    assert_eq!(c.rows(), a.rows(), "matmul_t out rows");
    assert_eq!(c.cols(), b.rows(), "matmul_t out cols");
    c.clear();
    let (m, kdim, n) = (a.rows(), a.cols(), b.rows());
    let av = View { data: a.as_slice(), rs: a.cols(), cs: 1 };
    let bv = View { data: b.as_slice(), rs: 1, cs: b.cols() };
    gemm_dispatch(m, kdim, n, av, bv, c.as_mut_slice());
}

/// `C = Aᵀ · Bᵀ` with A stored `k×m`, B stored `n×k` (C is `m×n`).
pub fn gemm_tt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.rows(), b.cols(), "tt inner dim");
    assert_eq!(c.rows(), a.cols(), "tt out rows");
    assert_eq!(c.cols(), b.rows(), "tt out cols");
    c.clear();
    let (m, kdim, n) = (a.cols(), a.rows(), b.rows());
    let av = View { data: a.as_slice(), rs: 1, cs: a.cols() };
    let bv = View { data: b.as_slice(), rs: 1, cs: b.cols() };
    gemm_dispatch(m, kdim, n, av, bv, c.as_mut_slice());
}

/// Symmetric gram `C = AᵀA` for A `m×k` (C is `k×k`): only the upper
/// triangle is accumulated (half the multiplies of a general `AᵀB`),
/// then mirrored — so the result is exactly symmetric by construction.
pub fn gram_into(a: &Mat, c: &mut Mat) {
    let (m, k) = a.shape();
    assert_eq!(c.shape(), (k, k), "gram out shape");
    c.clear();
    if m == 0 || k == 0 {
        return;
    }
    let work = m * k * k / 2;
    let nt = num_threads();
    if work < PAR_THRESHOLD || nt == 1 || m < 2 {
        gram_upper_rows(a, c.as_mut_slice(), 0, m, k);
    } else {
        let nt = nt.min(m);
        let chunk = m.div_ceil(nt);
        let cd = c.as_mut_slice();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..m.div_ceil(chunk))
                .map(|t| {
                    let r0 = t * chunk;
                    let r1 = (r0 + chunk).min(m);
                    s.spawn(move || {
                        let mut part = vec![0.0f32; k * k];
                        gram_upper_rows(a, &mut part, r0, r1, k);
                        part
                    })
                })
                .collect();
            for h in handles {
                let part = h.join().expect("gram worker");
                for (cv, pv) in cd.iter_mut().zip(&part) {
                    *cv += pv;
                }
            }
        });
    }
    // mirror the upper triangle into the lower
    let cd = c.as_mut_slice();
    for p in 0..k {
        for q in 0..p {
            cd[p * k + q] = cd[q * k + p];
        }
    }
}

/// Accumulate the upper triangle of `A[r0..r1, :]ᵀ · A[r0..r1, :]` into
/// `c` (a k×k buffer).
fn gram_upper_rows(a: &Mat, c: &mut [f32], r0: usize, r1: usize, k: usize) {
    for i in r0..r1 {
        let row = a.row(i);
        for p in 0..k {
            let ap = row[p];
            if ap == 0.0 {
                continue;
            }
            let crow = &mut c[p * k + p..(p + 1) * k];
            for (cv, &aq) in crow.iter_mut().zip(&row[p..]) {
                *cv += ap * aq;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Driver: threading over C row macro-panels, then the packed serial core
// ---------------------------------------------------------------------------

/// `C += OpA · OpB` over strided operand views; C is row-major `m×n`
/// (leading dimension n). Callers clear C first unless accumulating.
fn gemm_dispatch(m: usize, kdim: usize, n: usize, a: View, b: View, c: &mut [f32]) {
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    let work = m * kdim * n;
    let nt = num_threads();
    if work < PAR_THRESHOLD || nt == 1 || m < 2 {
        gemm_serial_packed(m, kdim, n, a, b, c);
        return;
    }
    let nt = nt.min(m);
    let chunk = m.div_ceil(nt);
    let c_chunks: Vec<&mut [f32]> = c.chunks_mut(chunk * n).collect();
    std::thread::scope(|s| {
        for (t, c_chunk) in c_chunks.into_iter().enumerate() {
            let a_sub = a.from_row(t * chunk);
            s.spawn(move || {
                let rows = c_chunk.len() / n;
                gemm_serial_packed(rows, kdim, n, a_sub, b, c_chunk);
            });
        }
    });
}

/// The serial packed core: 5-loop blocking with pack-then-microkernel.
fn gemm_serial_packed(m: usize, kdim: usize, n: usize, a: View, b: View, c: &mut [f32]) {
    let a_need = round_up(MC.min(m), MR) * KC.min(kdim);
    let b_need = KC.min(kdim) * round_up(NC.min(n), NR);
    SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let PackScratch { a: abuf, b: bbuf } = &mut *scratch;
        if abuf.len() < a_need {
            abuf.resize(a_need, 0.0);
        }
        if bbuf.len() < b_need {
            bbuf.resize(b_need, 0.0);
        }
        for jc in (0..n).step_by(NC) {
            let nb = NC.min(n - jc);
            for pc in (0..kdim).step_by(KC) {
                let kb = KC.min(kdim - pc);
                pack_b(b, pc, kb, jc, nb, bbuf);
                for ic in (0..m).step_by(MC) {
                    let mb = MC.min(m - ic);
                    pack_a(a, ic, mb, pc, kb, abuf);
                    macro_kernel(
                        mb,
                        kb,
                        nb,
                        (abuf.as_slice(), bbuf.as_slice()),
                        &mut c[ic * n + jc..],
                        n,
                    );
                }
            }
        }
    });
}

/// Pack the `mb×kb` block of A at `(ic, pc)` into MR-row micro-panels:
/// panel `ir/MR` holds `out[p*MR + i] = A[ic+ir+i, pc+p]`, zero-padded to
/// a full MR so the microkernel never branches on ragged rows.
fn pack_a(a: View, ic: usize, mb: usize, pc: usize, kb: usize, out: &mut [f32]) {
    let mut idx = 0;
    for ir in (0..mb).step_by(MR) {
        for p in 0..kb {
            for i in 0..MR {
                out[idx] = if ir + i < mb { a.at(ic + ir + i, pc + p) } else { 0.0 };
                idx += 1;
            }
        }
    }
}

/// Pack the `kb×nb` block of B at `(pc, jc)` into NR-column micro-panels:
/// panel `jr/NR` holds `out[p*NR + j] = B[pc+p, jc+jr+j]`, zero-padded to
/// a full NR.
fn pack_b(b: View, pc: usize, kb: usize, jc: usize, nb: usize, out: &mut [f32]) {
    let mut idx = 0;
    for jr in (0..nb).step_by(NR) {
        for p in 0..kb {
            for j in 0..NR {
                out[idx] = if jr + j < nb { b.at(pc + p, jc + jr + j) } else { 0.0 };
                idx += 1;
            }
        }
    }
}

/// Run the microkernel over every `MR×NR` tile of one packed macro-block.
/// `c` starts at the block's top-left corner of the full C (leading
/// dimension `ldc`).
fn macro_kernel(
    mb: usize,
    kb: usize,
    nb: usize,
    (apack, bpack): (&[f32], &[f32]),
    c: &mut [f32],
    ldc: usize,
) {
    for jr in (0..nb).step_by(NR) {
        let nr = NR.min(nb - jr);
        let bp = &bpack[(jr / NR) * (kb * NR)..][..kb * NR];
        for ir in (0..mb).step_by(MR) {
            let mr = MR.min(mb - ir);
            let ap = &apack[(ir / MR) * (MR * kb)..][..MR * kb];
            let c_off = ir * ldc + jr;
            if mr == MR && nr == NR {
                kernel_full(kb, ap, bp, &mut c[c_off..], ldc);
            } else {
                let acc = compute_acc(kb, ap, bp);
                // ragged edge: write back only the valid mr×nr corner
                for (i, arow) in acc.iter().enumerate().take(mr) {
                    let crow = &mut c[c_off + i * ldc..c_off + i * ldc + nr];
                    for (cv, &av) in crow.iter_mut().zip(arow.iter()) {
                        *cv += av;
                    }
                }
            }
        }
    }
}

/// The register tile: `acc[i][j] += Σ_p ap[p][i] · bp[p][j]` over packed
/// micro-panels. MR·NR accumulators stay in registers across the whole
/// kb depth — the entire point of packing.
#[inline(always)]
fn compute_acc(kb: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kb {
        let av: &[f32; MR] = ap[p * MR..p * MR + MR].try_into().unwrap();
        let bv: &[f32; NR] = bp[p * NR..p * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let ai = av[i];
            for j in 0..NR {
                acc[i][j] += ai * bv[j];
            }
        }
    }
    acc
}

/// Full-tile microkernel: accumulate into C directly.
#[inline(always)]
fn kernel_full(kb: usize, ap: &[f32], bp: &[f32], c: &mut [f32], ldc: usize) {
    let acc = compute_acc(kb, ap, bp);
    for (i, arow) in acc.iter().enumerate() {
        let crow = &mut c[i * ldc..i * ldc + NR];
        for (cv, &av) in crow.iter_mut().zip(arow.iter()) {
            *cv += av;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    // Naive-reference parity across adversarial shapes and all four
    // transpose variants lives in rust/tests/kernel_plane.rs (one copy,
    // exercised through the public Backend/kernel API); the tests here
    // cover what only this module can reach — blocking edges, the
    // accumulate contract, the symmetric gram, and the private serial
    // core vs the threaded dispatcher.

    #[test]
    fn empty_dims_are_fine() {
        // k = 0: the product of an m×0 and a 0×n matrix is all zeros
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let mut c = Mat::full(3, 4, 7.0);
        gemm_nn_into(&a, &b, &mut c, false);
        assert_eq!(c.as_slice(), &[0.0; 12][..]);
        // m = 0 / n = 0: empty outputs, no panic
        let mut c = Mat::zeros(0, 4);
        gemm_nn_into(&Mat::zeros(0, 5), &Mat::zeros(5, 4), &mut c, false);
        let mut c = Mat::zeros(3, 0);
        gemm_nn_into(&Mat::zeros(3, 5), &Mat::zeros(5, 0), &mut c, false);
        let mut g = Mat::zeros(0, 0);
        gram_into(&Mat::zeros(4, 0), &mut g);
    }

    #[test]
    fn accumulate_adds_on_top() {
        let a = Mat::eye(5);
        let b = Mat::full(5, 5, 2.0);
        let mut c = Mat::full(5, 5, 1.0);
        gemm_nn_into(&a, &b, &mut c, true);
        assert_eq!(c.as_slice(), &[3.0f32; 25][..]);
    }

    #[test]
    fn gram_matches_tn_and_is_exactly_symmetric() {
        let mut rng = Rng::new(501);
        for &(m, k) in &[(1, 1), (5, 3), (40, 8), (130, 17), (300, 33)] {
            let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
            let mut g = Mat::zeros(k, k);
            gram_into(&a, &mut g);
            let mut want = Mat::zeros(k, k);
            gemm_tn_into(&a, &a, &mut want);
            assert_close(g.as_slice(), want.as_slice(), 1e-3);
            for p in 0..k {
                for q in 0..k {
                    assert_eq!(g[(p, q)], g[(q, p)], "gram not exactly symmetric");
                }
            }
        }
    }

    #[test]
    fn threaded_path_matches_serial_result() {
        // large enough to cross PAR_THRESHOLD on multi-core hosts; on a
        // single-core host this still exercises the serial packed core
        let mut rng = Rng::new(502);
        let (m, kdim, n) = (190, 85, 110);
        let a = Mat::random_uniform(m, kdim, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(kdim, n, -1.0, 1.0, &mut rng);
        let mut c = Mat::zeros(m, n);
        gemm_nn_into(&a, &b, &mut c, false);
        let mut serial = Mat::zeros(m, n);
        gemm_serial_packed(
            m,
            kdim,
            n,
            View { data: a.as_slice(), rs: kdim, cs: 1 },
            View { data: b.as_slice(), rs: n, cs: 1 },
            serial.as_mut_slice(),
        );
        assert_close(c.as_slice(), serial.as_slice(), 1e-4);
    }
}
