//! Dense and sparse tensor substrate.
//!
//! pyDRESCALk's runtime is GEMM-dominated (paper §6.3.1: "run times are
//! dominated by matrix multiplication"), so this module carries a blocked,
//! thread-parallel single-precision GEMM plus the small set of elementwise
//! multiplicative-update primitives, a third-order tensor stored as
//! relation slices, and a CSR sparse matrix for the sparse experiments.
//!
//! The GEMM itself lives in [`kernel`] — a packed, SIMD-dispatched
//! microkernel plane — and [`half`] adds f16/bf16 *storage* formats that
//! widen to f32 on pack, so half-precision tiles and factor artifacts
//! run through the same f32 accumulator path.

pub mod dense;
pub mod half;
pub mod kernel;
pub mod ops;
pub mod sparse;
pub mod tensor3;

pub use dense::{Mat, SharedBuf};
pub use half::{DType, HalfMat, HalfTensor3};
pub use sparse::Csr;
pub use tensor3::Tensor3;
