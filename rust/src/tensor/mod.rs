//! Dense and sparse tensor substrate.
//!
//! pyDRESCALk's runtime is GEMM-dominated (paper §6.3.1: "run times are
//! dominated by matrix multiplication"), so this module carries a blocked,
//! thread-parallel single-precision GEMM plus the small set of elementwise
//! multiplicative-update primitives, a third-order tensor stored as
//! relation slices, and a CSR sparse matrix for the sparse experiments.

pub mod dense;
pub mod kernel;
pub mod ops;
pub mod sparse;
pub mod tensor3;

pub use dense::{Mat, SharedBuf};
pub use sparse::Csr;
pub use tensor3::Tensor3;
