//! Dense row-major f32 matrix. All products run on the packed
//! microkernel in [`super::kernel`]; the pre-packing blocked kernel is
//! kept as [`gemm_legacy`] for the `drescal bench` packed-vs-legacy
//! comparison and as a second implementation for parity tests.

use super::kernel;
use crate::rng::Rng;

/// Read-only storage a matrix can window into without copying — in
/// practice the memory-mapped payload of a dense shard file (see
/// `crate::store`). The trait object hands out the full `[f32]` payload;
/// each [`Mat`] keeps an offset/length window into it.
pub type SharedBuf = std::sync::Arc<dyn AsRef<[f32]> + Send + Sync>;

/// Matrix storage: an owned heap buffer, or a read-only window into a
/// shared (typically memory-mapped) buffer. Reads go straight to the
/// window; the first mutable access copies the window into an owned
/// buffer (copy-on-write), so resident mmap-backed tiles stay zero-copy
/// for the read-only training hot path.
#[derive(Clone)]
enum MatBuf {
    Owned(Vec<f32>),
    Shared { src: SharedBuf, off: usize, len: usize },
}

impl std::ops::Deref for MatBuf {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        match self {
            MatBuf::Owned(v) => v,
            MatBuf::Shared { src, off, len } => {
                let s: &[f32] = (**src).as_ref();
                &s[*off..*off + *len]
            }
        }
    }
}

impl std::ops::DerefMut for MatBuf {
    /// Copy-on-write: a shared window is copied into an owned buffer on
    /// the first mutable access, then mutated in place forever after.
    fn deref_mut(&mut self) -> &mut [f32] {
        if let MatBuf::Shared { .. } = self {
            let owned: Vec<f32> = self.to_vec();
            *self = MatBuf::Owned(owned);
        }
        match self {
            MatBuf::Owned(v) => v,
            MatBuf::Shared { .. } => unreachable!("shared storage was just copied"),
        }
    }
}

impl PartialEq for MatBuf {
    fn eq(&self, other: &MatBuf) -> bool {
        **self == **other
    }
}

impl std::fmt::Debug for MatBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MatBuf::Owned(v) => write!(f, "Owned({} f32)", v.len()),
            MatBuf::Shared { off, len, .. } => write!(f, "Shared {{ off: {off}, len: {len} }}"),
        }
    }
}

impl From<Vec<f32>> for MatBuf {
    fn from(v: Vec<f32>) -> MatBuf {
        MatBuf::Owned(v)
    }
}

/// Dense row-major single-precision matrix.
///
/// All pyDRESCALk factor math is f32 (the paper benchmarks in
/// single-precision arithmetic, §6.3).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: MatBuf,
}

/// Legacy GEMM block sizes (see EXPERIMENTS.md §Perf): MC×KC panels of A
/// stay L2-resident, KC×NC panels of B stream through L1. The packed
/// kernel has its own blocking in [`super::kernel`].
const MC: usize = 64;
const KC: usize = 256;
const NC: usize = 1024;

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: MatBuf::Owned(vec![0.0; rows * cols]) }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: MatBuf::Owned(vec![v; rows * cols]) }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data: MatBuf::Owned(data) }
    }

    /// Build a read-only matrix as a window of `rows·cols` f32s into a
    /// shared buffer starting at element `offset` — zero-copy: the matrix
    /// borrows the buffer (e.g. a memory-mapped shard payload) until its
    /// first mutation, which copies-on-write into an owned buffer.
    pub fn from_shared(rows: usize, cols: usize, src: SharedBuf, offset: usize) -> Self {
        let total = (*src).as_ref().len();
        assert!(offset + rows * cols <= total, "shared buffer window out of range");
        Mat { rows, cols, data: MatBuf::Shared { src, off: offset, len: rows * cols } }
    }

    /// Whether this matrix still reads from shared (e.g. memory-mapped)
    /// storage, i.e. no mutation has forced a copy yet.
    pub fn is_shared(&self) -> bool {
        matches!(self.data, MatBuf::Shared { .. })
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data: MatBuf::Owned(data) }
    }

    /// Uniform random entries in [lo, hi).
    pub fn random_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Extract column j as a vector.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Overwrite column j.
    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f32 {
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Elementwise `self += other`.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// Elementwise `self -= other`.
    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
    }

    /// Elementwise `self *= s`.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Elementwise product (Hadamard), in place.
    pub fn hadamard_assign(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a *= b;
        }
    }

    /// Fill with zeros, keeping the allocation.
    pub fn clear(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Copy `other`'s contents into this matrix (shapes must match).
    pub fn copy_from(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "copy_from shape mismatch");
        self.data.copy_from_slice(&other.data);
    }

    /// Surrender the backing buffer (used by the workspace arena to keep
    /// allocations alive across checkouts). A shared (mapped) matrix
    /// surrenders a copy — its storage belongs to the mapping.
    pub fn into_vec(self) -> Vec<f32> {
        match self.data {
            MatBuf::Owned(v) => v,
            shared => shared.to_vec(),
        }
    }

    /// Build a zero-filled `rows×cols` matrix on top of an existing
    /// buffer, reusing its allocation when the capacity suffices.
    pub fn from_buffer(rows: usize, cols: usize, mut buf: Vec<f32>) -> Self {
        buf.clear();
        buf.resize(rows * cols, 0.0);
        Mat { rows, cols, data: MatBuf::Owned(buf) }
    }

    /// Like [`Mat::from_buffer`] but without the zero-fill: whatever
    /// values the recycled buffer holds are kept (truncated or
    /// zero-extended to the target length). For write-into outputs that
    /// are fully overwritten before any read — the workspace arena's
    /// checkout path, where the skipped memset is a full extra pass over
    /// the largest serve buffer per batch.
    pub(crate) fn from_buffer_raw(rows: usize, cols: usize, mut buf: Vec<f32>) -> Self {
        let need = rows * cols;
        if buf.len() > need {
            buf.truncate(need);
        } else {
            buf.resize(need, 0.0);
        }
        Mat { rows, cols, data: MatBuf::Owned(buf) }
    }

    /// `C = A · B` allocating the output.
    pub fn matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm(self, b, &mut c, false);
        c
    }

    /// `C = Aᵀ · B` allocating the output.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        // Aᵀ(k×m)·B(m? ...): self is m×k -> result k × b.cols, requires
        // self.rows == b.rows.
        assert_eq!(self.rows, b.rows, "t_matmul inner dim");
        let mut c = Mat::zeros(self.cols, b.cols);
        gemm_at_b(self, b, &mut c);
        c
    }

    /// `C = A · Bᵀ` allocating the output.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_t inner dim");
        let mut c = Mat::zeros(self.rows, b.rows);
        gemm_a_bt(self, b, &mut c);
        c
    }

    /// Gram matrix `AᵀA` (k×k for an n×k input), exactly symmetric.
    pub fn gram(&self) -> Mat {
        let mut c = Mat::zeros(self.cols, self.cols);
        kernel::gram_into(self, &mut c);
        c
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Number of worker threads for the parallel GEMM path. Cached once.
pub fn num_threads() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("DRESCAL_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Work threshold (in fused multiply-adds) below which GEMM stays serial.
const PAR_THRESHOLD: usize = 1 << 20;

/// `C (+)= A · B` on the packed microkernel. If `accumulate` is false, C
/// is overwritten.
pub fn gemm(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    kernel::gemm_nn_into(a, b, c, accumulate);
}

/// `C (+)= A · B` on the legacy (unpacked) blocked kernel: the inner
/// j-loop is a unit-stride axpy over C and B rows, re-reading each C row
/// once per depth step. Kept for the `drescal bench` kernel section
/// (packed vs legacy) and as an independent parity reference; production
/// paths use [`gemm`].
pub fn gemm_legacy(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows, "gemm inner dim");
    assert_eq!(c.rows, a.rows, "gemm out rows");
    assert_eq!(c.cols, b.cols, "gemm out cols");
    if !accumulate {
        c.clear();
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let work = m * k * n;
    let nt = num_threads();
    if work < PAR_THRESHOLD || nt == 1 || m < 2 {
        gemm_serial(&a.data, &b.data, &mut c.data, m, k, n);
        return;
    }
    // Split C rows across threads.
    let nt = nt.min(m);
    let chunk = m.div_ceil(nt);
    let a_rows: Vec<&[f32]> = a.data.chunks(chunk * k).collect();
    let c_rows: Vec<&mut [f32]> = c.data.chunks_mut(chunk * n).collect();
    std::thread::scope(|s| {
        for (a_chunk, c_chunk) in a_rows.into_iter().zip(c_rows) {
            let b_data = &b.data;
            s.spawn(move || {
                let rows = a_chunk.len() / k;
                gemm_serial(a_chunk, b_data, c_chunk, rows, k, n);
            });
        }
    });
}

/// Serial blocked kernel: C += A·B with A m×k, B k×n (all row-major).
fn gemm_serial(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                for i in ic..ic + mb {
                    let arow = &a[i * k + pc..i * k + pc + kb];
                    let crow = &mut c[i * n + jc..i * n + jc + nb];
                    for (p, &aip) in arow.iter().enumerate() {
                        if aip == 0.0 {
                            continue;
                        }
                        let brow = &b[(pc + p) * n + jc..(pc + p) * n + jc + nb];
                        // unit-stride axpy — auto-vectorized
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aip * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = Aᵀ · B` without materializing Aᵀ: A is m×k, B is m×n, C is k×n.
/// Runs the packed microkernel reading A through a transposed view.
pub fn gemm_at_b(a: &Mat, b: &Mat, c: &mut Mat) {
    kernel::gemm_tn_into(a, b, c);
}

/// `C = A · Bᵀ`: A is m×k, B is n×k, C is m×n. Runs the packed
/// microkernel reading B through a transposed view.
pub fn gemm_a_bt(a: &Mat, b: &Mat, c: &mut Mat) {
    kernel::gemm_nt_into(a, b, c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f64;
                for p in 0..a.cols() {
                    acc += a[(i, p)] as f64 * b[(p, j)] as f64;
                }
                c[(i, j)] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_small_exact() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 33), (64, 64, 64), (70, 130, 50)] {
            let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert_close(got.as_slice(), want.as_slice(), 1e-4);
        }
    }

    #[test]
    fn matmul_parallel_path_matches_naive() {
        let mut rng = Rng::new(2);
        // big enough to take the threaded path
        let (m, k, n) = (150, 120, 110);
        let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
        let got = a.matmul(&b);
        let want = naive_matmul(&a, &b);
        assert_close(got.as_slice(), want.as_slice(), 1e-3);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(4, 3, 5), (33, 7, 11), (120, 40, 60)] {
            let a = Mat::random_uniform(m, k, 0.0, 1.0, &mut rng);
            let b = Mat::random_uniform(m, n, 0.0, 1.0, &mut rng);
            let got = a.t_matmul(&b);
            let want = a.transpose().matmul(&b);
            assert_close(got.as_slice(), want.as_slice(), 1e-3);
        }
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(4);
        for &(m, k, n) in &[(4, 3, 5), (33, 7, 11), (100, 50, 80)] {
            let a = Mat::random_uniform(m, k, 0.0, 1.0, &mut rng);
            let b = Mat::random_uniform(n, k, 0.0, 1.0, &mut rng);
            let got = a.matmul_t(&b);
            let want = a.matmul(&b.transpose());
            assert_close(got.as_slice(), want.as_slice(), 1e-3);
        }
    }

    #[test]
    fn gram_is_symmetric() {
        let mut rng = Rng::new(5);
        let a = Mat::random_uniform(40, 8, 0.0, 1.0, &mut rng);
        let g = a.gram();
        for i in 0..8 {
            for j in 0..8 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(6);
        let a = Mat::random_uniform(37, 53, -1.0, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    // packed-vs-legacy agreement (serial and threaded) is covered once,
    // in rust/tests/kernel_plane.rs

    #[test]
    fn from_buffer_reuses_capacity() {
        let big = Mat::zeros(10, 10).into_vec();
        let cap = big.capacity();
        let m = Mat::from_buffer(3, 4, big);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.as_slice(), &[0.0; 12][..]);
        assert!(m.into_vec().capacity() >= cap.min(100));
        let mut a = Mat::from_vec(1, 2, vec![5.0, 6.0]);
        a.copy_from(&Mat::from_vec(1, 2, vec![1.0, 2.0]));
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
    }

    #[test]
    fn gemm_accumulate() {
        let a = Mat::eye(3);
        let b = Mat::full(3, 3, 2.0);
        let mut c = Mat::full(3, 3, 1.0);
        gemm(&a, &b, &mut c, true);
        assert_eq!(c.as_slice(), &[3.0f32; 9][..]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(7);
        let a = Mat::random_uniform(9, 9, -1.0, 1.0, &mut rng);
        let i = Mat::eye(9);
        assert_close(a.matmul(&i).as_slice(), a.as_slice(), 1e-6);
        assert_close(i.matmul(&a).as_slice(), a.as_slice(), 1e-6);
    }

    #[test]
    fn norm_fro_known() {
        let a = Mat::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        assert!((a.norm_fro() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Mat::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Mat::from_vec(1, 3, vec![4., 5., 6.]);
        a.add_assign(&b);
        assert_eq!(a.as_slice(), &[5., 7., 9.]);
        a.sub_assign(&b);
        assert_eq!(a.as_slice(), &[1., 2., 3.]);
        a.hadamard_assign(&b);
        assert_eq!(a.as_slice(), &[4., 10., 18.]);
        a.scale(0.5);
        assert_eq!(a.as_slice(), &[2., 5., 9.]);
    }

    #[test]
    fn col_get_set() {
        let mut a = Mat::zeros(3, 2);
        a.set_col(1, &[1., 2., 3.]);
        assert_eq!(a.col(1), vec![1., 2., 3.]);
        assert_eq!(a.col(0), vec![0., 0., 0.]);
    }

    /// Shared storage: two matrices window one buffer zero-copy; reads and
    /// products see the windowed values; the first mutation copies on
    /// write without disturbing the sibling window.
    #[test]
    fn shared_windows_are_zero_copy_until_mutated() {
        let backing: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let src: SharedBuf = std::sync::Arc::new(backing);
        let a = Mat::from_shared(2, 3, std::sync::Arc::clone(&src), 0);
        let mut b = Mat::from_shared(2, 3, std::sync::Arc::clone(&src), 6);
        assert!(a.is_shared() && b.is_shared());
        assert_eq!(a.as_slice(), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(b[(1, 2)], 11.0);
        assert_eq!(b.row(0), &[6., 7., 8.]);
        // products read through the window
        let c = a.matmul(&b.transpose());
        assert_eq!(c.shape(), (2, 2));
        assert!(a.is_shared(), "a read must not trigger the copy");
        // first mutation copies on write; the sibling window is untouched
        b[(0, 0)] = -1.0;
        assert!(!b.is_shared());
        assert_eq!(b[(0, 0)], -1.0);
        assert_eq!(a.as_slice(), &[0., 1., 2., 3., 4., 5.]);
        // equality and clone behave like owned matrices
        let owned = Mat::from_vec(2, 3, (0..6).map(|i| i as f32).collect());
        assert_eq!(a, owned);
        let a2 = a.clone();
        assert!(a2.is_shared());
        assert_eq!(a2.into_vec(), vec![0., 1., 2., 3., 4., 5.]);
    }
}
