//! CSR sparse matrix for the sparse-tensor experiments.
//!
//! The paper stores sparse X in compressed-sparse-row format (§4.1) and
//! notes that all products involving X against the dense factors produce
//! dense results, so communication volume is unchanged versus dense — only
//! local compute shrinks with density. This module supplies exactly those
//! products: `CSR·dense`, `CSRᵀ·dense`, plus perturbation over the nonzero
//! pattern (Alg 4's sparse branch).

use std::fmt;
use std::sync::OnceLock;

use super::dense::Mat;
use crate::rng::Rng;

/// Compressed sparse row matrix (f32 values).
pub struct Csr {
    rows: usize,
    cols: usize,
    /// row i's entries live in indices `indptr[i]..indptr[i+1]`
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f32>,
    /// Lazily built transpose for the threaded [`Csr::t_matmul_dense`]
    /// path, amortized across the MU iterations that hit one resident
    /// tile. Excluded from `Clone` (a clone may be mutated), `PartialEq`,
    /// and `Debug`.
    t_cache: OnceLock<Box<Csr>>,
}

impl Clone for Csr {
    fn clone(&self) -> Csr {
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values: self.values.clone(),
            // never copy the cache: `perturb` mutates the clone's values
            t_cache: OnceLock::new(),
        }
    }
}

impl PartialEq for Csr {
    fn eq(&self, other: &Csr) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.indptr == other.indptr
            && self.indices == other.indices
            && self.values == other.values
    }
}

impl fmt::Debug for Csr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Csr")
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("nnz", &self.nnz())
            .finish()
    }
}

impl Csr {
    /// Build from COO triplets (row, col, value). Duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut trips: Vec<(usize, usize, f32)>) -> Self {
        trips.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(trips.len());
        let mut values: Vec<f32> = Vec::with_capacity(trips.len());
        for &(r, c, v) in &trips {
            assert!(r < rows && c < cols, "triplet out of bounds");
            if !indices.is_empty()
                && *indptr.get(r + 1).unwrap() > indptr[r]
                && indices.last() == Some(&c)
                && indptr[r + 1] == indices.len()
            {
                // same (r, c) as previous entry of the same row: accumulate
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
                indptr[r + 1] = indices.len();
            }
        }
        // make indptr cumulative over empty rows
        for i in 1..=rows {
            if indptr[i] < indptr[i - 1] {
                indptr[i] = indptr[i - 1];
            }
        }
        Csr { rows, cols, indptr, indices, values, t_cache: OnceLock::new() }
    }

    /// Rebuild a CSR matrix from its raw arrays (the shard-file decode
    /// path), validating every structural invariant so corrupt or
    /// hand-crafted inputs surface as typed errors instead of
    /// out-of-bounds panics in the SpMM kernels later: `indptr` must be
    /// a monotone ramp of length `rows + 1` from 0 to `nnz`, arrays must
    /// agree in length, and every column index must be in range.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f32>,
    ) -> crate::error::Result<Self> {
        if indptr.len() != rows + 1 {
            crate::bail!("CSR indptr has {} entries for {} rows", indptr.len(), rows);
        }
        if indptr[0] != 0 {
            crate::bail!("CSR indptr must start at 0, got {}", indptr[0]);
        }
        for i in 1..indptr.len() {
            if indptr[i] < indptr[i - 1] {
                crate::bail!(
                    "CSR indptr is not monotone at row {}: {} < {}",
                    i - 1,
                    indptr[i],
                    indptr[i - 1]
                );
            }
        }
        let nnz = *indptr.last().unwrap();
        if indices.len() != nnz || values.len() != nnz {
            crate::bail!(
                "CSR arrays disagree: indptr ends at {nnz} but indices/values hold {}/{}",
                indices.len(),
                values.len()
            );
        }
        if let Some(&bad) = indices.iter().find(|&&j| j >= cols) {
            crate::bail!("CSR column index {bad} out of range for {cols} columns");
        }
        Ok(Csr { rows, cols, indptr, indices, values, t_cache: OnceLock::new() })
    }

    /// Row-pointer array (`rows + 1` entries; row i spans
    /// `indptr[i]..indptr[i+1]`).
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Column indices of the stored entries, row-major.
    #[inline]
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Values of the stored entries, parallel to [`Csr::indices`].
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Convert a dense matrix, keeping entries with |v| > 0.
    pub fn from_dense(a: &Mat) -> Self {
        let mut trips = Vec::new();
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                let v = a[(i, j)];
                if v != 0.0 {
                    trips.push((i, j, v));
                }
            }
        }
        Csr::from_triplets(a.rows(), a.cols(), trips)
    }

    /// Random sparse non-negative matrix with the given density. Collided
    /// (r, c) draws are redrawn, so `nnz` hits the target exactly instead
    /// of silently undershooting when duplicates collapse.
    pub fn random(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Self {
        let cells = rows * cols;
        let nnz_target = ((cells as f64 * density).round() as usize).min(cells);
        let mut seen = std::collections::HashSet::with_capacity(nnz_target * 2);
        let mut trips = Vec::with_capacity(nnz_target);
        while trips.len() < nnz_target {
            let r = rng.below(rows);
            let c = rng.below(cols);
            if seen.insert((r, c)) {
                trips.push((r, c, rng.uniform_f32() + 0.01));
            }
        }
        Csr::from_triplets(rows, cols, trips)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Approximate memory footprint in bytes: values + column indices +
    /// row pointers, plus the lazily built transpose cache once it
    /// exists (it roughly doubles the footprint after the first
    /// above-threshold `t_matmul_dense`).
    pub fn resident_bytes(&self) -> usize {
        let w = std::mem::size_of::<usize>();
        let own = self.nnz() * (4 + w) + (self.rows + 1) * w;
        own + self.t_cache.get().map_or(0, |t| t.resident_bytes())
    }

    /// Fill fraction.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows * self.cols) as f64
        }
    }

    /// Densify (for small tests only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for idx in self.indptr[i]..self.indptr[i + 1] {
                m[(i, self.indices[idx])] += self.values[idx];
            }
        }
        m
    }

    /// Transposed copy (CSR of the transpose, built by counting sort).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c + 1] += 1;
        }
        for i in 1..=self.cols {
            counts[i] += counts[i - 1];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        for i in 0..self.rows {
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let c = self.indices[idx];
                let dst = cursor[c];
                cursor[c] += 1;
                indices[dst] = i;
                values[dst] = self.values[idx];
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            indptr,
            indices,
            values,
            t_cache: OnceLock::new(),
        }
    }

    /// Row `i`'s stored entries as parallel `(column, value)` slices —
    /// lets callers (the residual accumulator, fill analyses) walk the
    /// CSR structure directly instead of densifying the tile.
    #[inline]
    pub fn row_entries(&self, i: usize) -> (&[usize], &[f32]) {
        let span = self.indptr[i]..self.indptr[i + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// `C = self · B` with dense B — the sparse hot path (X_t · A).
    pub fn matmul_dense(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols());
        self.matmul_dense_into(b, &mut c);
        c
    }

    /// Write-into form of [`Csr::matmul_dense`]: `c` must be
    /// `rows × b.cols()` and is overwritten (workspace buffers arrive
    /// with stale contents).
    pub fn matmul_dense_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, b.rows(), "spmm inner dim");
        assert_eq!(c.shape(), (self.rows, b.cols()), "spmm out shape");
        let n = b.cols();
        c.clear();
        let nt = crate::tensor::dense::num_threads();
        if self.nnz() * n < (1 << 20) || nt == 1 || self.rows < 2 {
            self.spmm_rows(b, c, 0, self.rows);
            return;
        }
        let nt = nt.min(self.rows);
        let chunk = self.rows.div_ceil(nt);
        let c_chunks: Vec<&mut [f32]> = c.as_mut_slice().chunks_mut(chunk * n).collect();
        std::thread::scope(|s| {
            for (t, c_chunk) in c_chunks.into_iter().enumerate() {
                let me = &self;
                s.spawn(move || {
                    let r0 = t * chunk;
                    let r1 = (r0 + chunk).min(me.rows);
                    me.spmm_rows_into(b, c_chunk, r0, r1);
                });
            }
        });
    }

    fn spmm_rows(&self, b: &Mat, c: &mut Mat, r0: usize, r1: usize) {
        let n = b.cols();
        let buf = &mut c.as_mut_slice()[r0 * n..r1 * n];
        self.spmm_rows_into(b, buf, r0, r1);
    }

    /// C rows r0..r1 (buffer holds only those rows) += X[r0..r1,:]·B.
    fn spmm_rows_into(&self, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
        let n = b.cols();
        for i in r0..r1 {
            let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let v = self.values[idx];
                let brow = b.row(self.indices[idx]);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += v * bv;
                }
            }
        }
    }

    /// `C = selfᵀ · B` — the XᵀAR hot path (Alg 3 line 12). Small inputs
    /// use the allocation-free serial scatter; above the same work
    /// threshold as [`Csr::matmul_dense`], the cached counting-sort
    /// transpose (built once per matrix, amortized over the MU
    /// iterations that hit one resident tile) turns the scatter into a
    /// row-parallel SpMM on the threaded [`Csr::matmul_dense`] path. (A
    /// column-partitioned scatter would instead make every thread scan
    /// all nnz, paying O(threads·nnz) redundant traversal per call.)
    pub fn t_matmul_dense(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.cols, b.cols());
        self.t_matmul_dense_into(b, &mut c);
        c
    }

    /// Write-into form of [`Csr::t_matmul_dense`]: `c` must be
    /// `cols × b.cols()` and is overwritten.
    pub fn t_matmul_dense_into(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(self.rows, b.rows(), "spmm_t inner dim");
        assert_eq!(c.shape(), (self.cols, b.cols()), "spmm_t out shape");
        let n = b.cols();
        c.clear();
        let nt = crate::tensor::dense::num_threads();
        if self.nnz() * n < (1 << 20) || nt == 1 || self.cols < 2 {
            // serial scatter: for each nonzero (i, j, v): C[j,:] += v·B[i,:]
            let cd = c.as_mut_slice();
            for i in 0..self.rows {
                let brow = b.row(i);
                for idx in self.indptr[i]..self.indptr[i + 1] {
                    let j = self.indices[idx];
                    let v = self.values[idx];
                    let crow = &mut cd[j * n..(j + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += v * bv;
                    }
                }
            }
            return;
        }
        self.t_cache.get_or_init(|| Box::new(self.transpose())).matmul_dense_into(b, c)
    }

    /// Multiply every stored value by a fresh uniform factor in
    /// [1−δ, 1+δ] — the sparse branch of Alg 4 (perturb nonzeros only).
    pub fn perturb(&self, delta: f32, rng: &mut Rng) -> Csr {
        let mut out = self.clone();
        for v in out.values.iter_mut() {
            *v *= rng.uniform_range(1.0 - delta, 1.0 + delta);
        }
        out
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f32 {
        self.values.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Extract the tile rows r0..r1 × cols c0..c1 as a new CSR (local rank
    /// tile in the 2D grid layout).
    pub fn tile(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Csr {
        let mut trips = Vec::new();
        for i in r0..r1 {
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let c = self.indices[idx];
                if c >= c0 && c < c1 {
                    trips.push((i - r0, c - c0, self.values[idx]));
                }
            }
        }
        Csr::from_triplets(r1 - r0, c1 - c0, trips)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    fn sample() -> Csr {
        Csr::from_triplets(3, 4, vec![(0, 1, 2.0), (1, 0, 3.0), (1, 3, 4.0), (2, 2, 5.0)])
    }

    #[test]
    fn row_entries_walk_the_structure() {
        let s = sample();
        assert_eq!(s.row_entries(0), (&[1usize][..], &[2.0f32][..]));
        let (cols, vals) = s.row_entries(1);
        assert_eq!(cols, &[0, 3]);
        assert_eq!(vals, &[3.0, 4.0]);
        let empty = Csr::from_triplets(2, 2, vec![]);
        assert_eq!(empty.row_entries(0).0.len(), 0);
    }

    #[test]
    fn into_products_overwrite_stale_buffers() {
        let mut rng = Rng::new(38);
        let s = Csr::random(20, 15, 0.2, &mut rng);
        let b = Mat::random_uniform(15, 4, -1.0, 1.0, &mut rng);
        let mut c = Mat::full(20, 4, 9.0);
        s.matmul_dense_into(&b, &mut c);
        assert_close(c.as_slice(), s.matmul_dense(&b).as_slice(), 1e-6);
        let bt = Mat::random_uniform(20, 4, -1.0, 1.0, &mut rng);
        let mut ct = Mat::full(15, 4, -3.0);
        s.t_matmul_dense_into(&bt, &mut ct);
        assert_close(ct.as_slice(), s.t_matmul_dense(&bt).as_slice(), 1e-6);
    }

    #[test]
    fn from_triplets_and_to_dense() {
        let s = sample();
        assert_eq!(s.nnz(), 4);
        let d = s.to_dense();
        assert_eq!(d[(0, 1)], 2.0);
        assert_eq!(d[(1, 0)], 3.0);
        assert_eq!(d[(1, 3)], 4.0);
        assert_eq!(d[(2, 2)], 5.0);
        assert_eq!(d.sum(), 14.0);
    }

    #[test]
    fn duplicate_triplets_sum() {
        let s = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0)]);
        assert_eq!(s.to_dense()[(0, 0)], 3.0);
        assert_eq!(s.nnz(), 1);
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(30);
        let d = Mat::random_uniform(7, 5, 0.0, 1.0, &mut rng);
        let s = Csr::from_dense(&d);
        assert_close(s.to_dense().as_slice(), d.as_slice(), 1e-6);
    }

    #[test]
    fn transpose_matches_dense() {
        let s = sample();
        let t = s.transpose();
        assert_eq!(t.rows(), 4);
        assert_eq!(t.cols(), 3);
        assert_close(
            t.to_dense().as_slice(),
            s.to_dense().transpose().as_slice(),
            1e-6,
        );
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(31);
        let s = Csr::random(40, 30, 0.1, &mut rng);
        let b = Mat::random_uniform(30, 8, -1.0, 1.0, &mut rng);
        let got = s.matmul_dense(&b);
        let want = s.to_dense().matmul(&b);
        assert_close(got.as_slice(), want.as_slice(), 1e-4);
    }

    #[test]
    fn spmm_t_matches_dense() {
        let mut rng = Rng::new(32);
        let s = Csr::random(40, 30, 0.1, &mut rng);
        let b = Mat::random_uniform(40, 8, -1.0, 1.0, &mut rng);
        let got = s.t_matmul_dense(&b);
        let want = s.to_dense().transpose().matmul(&b);
        assert_close(got.as_slice(), want.as_slice(), 1e-4);
    }

    #[test]
    fn random_density() {
        let mut rng = Rng::new(33);
        // collisions are redrawn, so the target is hit exactly
        let s = Csr::random(100, 100, 0.05, &mut rng);
        assert_eq!(s.nnz(), 500);
        assert_eq!(s.density(), 0.05);
        // saturation: a full matrix is reachable without spinning forever
        let f = Csr::random(8, 8, 1.0, &mut rng);
        assert_eq!(f.nnz(), 64);
    }

    /// Sized above the `nnz·k ≥ 2²⁰` threading threshold so the
    /// cached-transpose + threaded-SpMM path runs; it must match the
    /// dense transpose product.
    #[test]
    fn spmm_t_threaded_matches_dense() {
        let mut rng = Rng::new(36);
        let s = Csr::random(600, 600, 0.5, &mut rng);
        let b = Mat::random_uniform(600, 8, -1.0, 1.0, &mut rng);
        assert!(s.nnz() * b.cols() >= 1 << 20, "test no longer crosses the threshold");
        let got = s.t_matmul_dense(&b);
        let want = s.to_dense().transpose().matmul(&b);
        assert_close(got.as_slice(), want.as_slice(), 2e-3);
    }

    /// The cached transpose is reused across calls and never leaks into a
    /// clone whose values diverge (perturb mutates the clone in place).
    #[test]
    fn spmm_t_cache_repeats_and_resets_on_clone() {
        let mut rng = Rng::new(37);
        let s = Csr::random(600, 600, 0.5, &mut rng);
        let b = Mat::random_uniform(600, 8, -1.0, 1.0, &mut rng);
        let first = s.t_matmul_dense(&b);
        let second = s.t_matmul_dense(&b); // served from the cache
        assert_eq!(first.as_slice(), second.as_slice());
        // a perturbed clone must not see the parent's stale transpose
        let p = s.perturb(0.5, &mut rng);
        let got = p.t_matmul_dense(&b);
        let want = p.to_dense().transpose().matmul(&b);
        assert_close(got.as_slice(), want.as_slice(), 2e-3);
    }

    #[test]
    fn perturb_keeps_pattern_and_bounds() {
        let mut rng = Rng::new(34);
        let s = Csr::random(20, 20, 0.2, &mut rng);
        let p = s.perturb(0.03, &mut rng);
        assert_eq!(p.nnz(), s.nnz());
        for (a, b) in s.values.iter().zip(&p.values) {
            let ratio = b / a;
            assert!(ratio >= 0.97 - 1e-5 && ratio <= 1.03 + 1e-5);
        }
    }

    #[test]
    fn tile_matches_dense_tile() {
        let mut rng = Rng::new(35);
        let s = Csr::random(16, 16, 0.3, &mut rng);
        let t = s.tile(4, 12, 8, 16);
        let d = s.to_dense();
        let want = Mat::from_fn(8, 8, |i, j| d[(4 + i, 8 + j)]);
        assert_close(t.to_dense().as_slice(), want.as_slice(), 1e-6);
    }

    /// `from_parts` accepts exactly the arrays `from_triplets` builds and
    /// rejects every structural corruption a damaged shard could decode
    /// into.
    #[test]
    fn from_parts_validates_structure() {
        let s = sample();
        let rebuilt = Csr::from_parts(
            s.rows(),
            s.cols(),
            s.indptr().to_vec(),
            s.indices().to_vec(),
            s.values().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt, s);
        let bad = |r: crate::error::Result<Csr>, what: &str| {
            let e = r.unwrap_err().to_string();
            assert!(e.contains("CSR"), "{what}: {e}");
        };
        // wrong indptr length
        bad(Csr::from_parts(3, 4, vec![0, 1], vec![0], vec![1.0]), "short indptr");
        // indptr not starting at zero
        bad(
            Csr::from_parts(1, 4, vec![1, 1], vec![], vec![]),
            "indptr[0] != 0",
        );
        // non-monotone indptr
        bad(
            Csr::from_parts(2, 4, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0]),
            "non-monotone",
        );
        // array length mismatch
        bad(
            Csr::from_parts(1, 4, vec![0, 2], vec![0], vec![1.0, 2.0]),
            "length mismatch",
        );
        // column index out of range
        bad(
            Csr::from_parts(1, 4, vec![0, 1], vec![9], vec![1.0]),
            "column out of range",
        );
    }

    #[test]
    fn empty_rows_ok() {
        let s = Csr::from_triplets(5, 5, vec![(4, 4, 1.0)]);
        assert_eq!(s.nnz(), 1);
        let b = Mat::eye(5);
        let c = s.matmul_dense(&b);
        assert_eq!(c[(4, 4)], 1.0);
        assert_eq!(c.sum(), 1.0);
    }
}
