//! Cluster-scale replay: predict per-op runtimes of distributed RESCAL(k)
//! at the paper's scales (up to 23k ranks, 9.5 EB tensors) from the §5
//! complexity analysis plus a calibrated machine model.
//!
//! This is the documented substitution (DESIGN.md §3) for the Grizzly and
//! Kodiak clusters: the *measured* small-p runs come from the real
//! implementation in `coordinator`; the *modeled* large-p points use these
//! formulas with α-β network parameters and per-rank compute rates, either
//! the built-in hardware presets or rates calibrated from a live
//! microbenchmark.

pub mod exascale;

use crate::comm::model::{ComputeModel, NetworkModel};

/// One modeled machine: per-rank compute + interconnect.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub compute: ComputeModel,
    pub network: NetworkModel,
}

impl Machine {
    /// CPU cluster preset (Grizzly-like).
    pub fn cpu_cluster() -> Self {
        Machine { compute: ComputeModel::grizzly_cpu_rank(), network: NetworkModel::omnipath() }
    }

    /// GPU cluster preset (Kodiak-like).
    pub fn gpu_cluster() -> Self {
        Machine {
            compute: ComputeModel::kodiak_p100_rank(),
            network: NetworkModel::infiniband_gpu(),
        }
    }

    /// Calibrated machine: measured dense rate (FLOP/s) on this host, with
    /// local-memory "interconnect" parameters measured from the virtual
    /// MPI collectives.
    pub fn calibrated(dense_flops: f64, alpha: f64, beta: f64) -> Self {
        Machine {
            compute: ComputeModel { flops: dense_flops, sparse_flops: dense_flops / 20.0 },
            network: NetworkModel { alpha, beta },
        }
    }
}

/// Modeled per-iteration timing breakdown (seconds), matching the
/// categories of `comm::CommOp`.
#[derive(Clone, Debug, Default)]
pub struct PredictedIter {
    pub gram_mul: f64,
    pub matrix_mul: f64,
    pub row_reduce: f64,
    pub column_reduce: f64,
    pub row_broadcast: f64,
    pub column_broadcast: f64,
}

impl PredictedIter {
    pub fn compute(&self) -> f64 {
        self.gram_mul + self.matrix_mul
    }

    pub fn comm(&self) -> f64 {
        self.row_reduce + self.column_reduce + self.row_broadcast + self.column_broadcast
    }

    pub fn total(&self) -> f64 {
        self.compute() + self.comm()
    }
}

/// Predict one MU iteration of Algorithm 3 for an n×n×m tensor of the
/// given density (1.0 = dense) on a √p×√p grid.
///
/// Operation counts follow Algorithm 3 exactly; collective sizes follow
/// §5.1.2 (all over √p ranks).
pub fn predict_rescal_iter(
    n: usize,
    m: usize,
    k: usize,
    p: usize,
    density: f64,
    machine: &Machine,
) -> PredictedIter {
    let q = (p as f64).sqrt().round().max(1.0);
    let n_loc = n as f64 / q;
    let (mf, kf) = (m as f64, k as f64);
    let net = &machine.network;
    let comp = &machine.compute;
    let qp = q as usize;

    let mut out = PredictedIter::default();
    // line 3: local gram of A^(j): 2·n_loc·k² flops
    out.gram_mul = comp.dense_seconds(2.0 * n_loc * kf * kf);
    // per slice: the two tile GEMMs (density-scaled) + skinny GEMMs + k³
    let tile_flop = 2.0 * n_loc * n_loc * kf * density.min(1.0);
    let tile_secs = if density >= 1.0 {
        comp.dense_seconds(2.0 * tile_flop)
    } else {
        comp.sparse_seconds(2.0 * tile_flop)
    };
    let skinny = comp.dense_seconds(mf * 6.0 * 2.0 * n_loc * kf * kf);
    let small = comp.dense_seconds(mf * 4.0 * 2.0 * kf * kf * kf);
    out.matrix_mul = mf * tile_secs + skinny + small;
    // collectives per slice: XA row all_reduce (n_loc·k), ATXA col
    // all_reduce (k²), XTAR col all_reduce (n_loc·k), XTAR row broadcast
    // (n_loc·k); per iteration: ATA row all_reduce (k²), A col broadcast
    let fk = 4.0; // bytes per f32
    out.row_reduce = mf * net.all_reduce(qp, (n_loc * kf * fk) as usize)
        + net.all_reduce(qp, (kf * kf * fk) as usize);
    out.column_reduce = mf
        * (net.all_reduce(qp, (kf * kf * fk) as usize)
            + net.all_reduce(qp, (n_loc * kf * fk) as usize));
    out.row_broadcast = mf * net.broadcast(qp, (n_loc * kf * fk) as usize);
    out.column_broadcast = net.broadcast(qp, (n_loc * kf * fk) as usize);
    out
}

/// Predict one clustering + silhouette pass (Algorithms 5 & 6) per §5.2.
pub fn predict_clustering(
    n: usize,
    k: usize,
    r: usize,
    p: usize,
    machine: &Machine,
    cluster_iters: usize,
) -> (f64, f64) {
    let q = (p as f64).sqrt().round().max(1.0);
    let n_loc = n as f64 / q;
    let (kf, rf) = (k as f64, r as f64);
    let comp = &machine.compute;
    let net = &machine.network;
    let qp = q as usize;
    // clustering per iteration: r partial similarities (2·n_loc·k²) +
    // one k²r all_reduce + LSA O(k³)·r + median O(n_loc·k·r log r)
    let cl_compute = cluster_iters as f64
        * (comp.dense_seconds(rf * 2.0 * n_loc * kf * kf)
            + comp.dense_seconds(rf * kf * kf * kf)
            + comp.dense_seconds(n_loc * kf * rf * rf.log2().max(1.0)));
    let cl_comm = cluster_iters as f64 * net.all_reduce(qp, (kf * kf * rf * 4.0) as usize);
    // silhouette: k²r² inner products of length n_loc + one k²r² reduce
    let sil_compute = comp.dense_seconds(kf * kf * rf * rf * 2.0 * n_loc);
    let sil_comm = net.all_reduce(qp, (kf * kf * rf * rf * 4.0) as usize);
    (cl_compute + sil_compute, cl_comm + sil_comm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strong_scaling_compute_drops_linearly() {
        let m = Machine::cpu_cluster();
        let t1 = predict_rescal_iter(8192, 20, 10, 1, 1.0, &m);
        let t16 = predict_rescal_iter(8192, 20, 10, 16, 1.0, &m);
        let ratio = t1.compute() / t16.compute();
        assert!(ratio > 10.0 && ratio < 18.0, "ratio={ratio}");
    }

    #[test]
    fn weak_scaling_flat_compute() {
        let m = Machine::cpu_cluster();
        let base = predict_rescal_iter(4096, 20, 10, 1, 1.0, &m);
        let scaled = predict_rescal_iter(4096 * 4, 20, 10, 16, 1.0, &m);
        let ratio = scaled.compute() / base.compute();
        assert!(ratio > 0.9 && ratio < 1.1, "ratio={ratio}");
    }

    #[test]
    fn comm_grows_with_p_in_weak_scaling() {
        let m = Machine::cpu_cluster();
        let small = predict_rescal_iter(4096, 20, 10, 4, 1.0, &m);
        let large = predict_rescal_iter(4096 * 8, 20, 10, 256, 1.0, &m);
        assert!(large.comm() > small.comm());
    }

    #[test]
    fn gpu_compute_at_least_10x_faster() {
        let cpu = Machine::cpu_cluster();
        let gpu = Machine::gpu_cluster();
        let tc = predict_rescal_iter(8192, 20, 10, 4, 1.0, &cpu);
        let tg = predict_rescal_iter(8192, 20, 10, 4, 1.0, &gpu);
        assert!(tc.compute() / tg.compute() >= 10.0);
    }

    #[test]
    fn gpu_becomes_comm_bound_where_cpu_is_not() {
        // paper Fig 9: GPU weak scaling is communication-dominated
        let cpu = Machine::cpu_cluster();
        let gpu = Machine::gpu_cluster();
        let n = 8192 * 8;
        let tc = predict_rescal_iter(n, 20, 10, 64, 1.0, &cpu);
        let tg = predict_rescal_iter(n, 20, 10, 64, 1.0, &gpu);
        let cpu_frac = tc.comm() / tc.total();
        let gpu_frac = tg.comm() / tg.total();
        assert!(gpu_frac > cpu_frac, "gpu {gpu_frac} vs cpu {cpu_frac}");
        assert!(gpu_frac > 0.5, "gpu should be comm-bound: {gpu_frac}");
    }

    #[test]
    fn sparse_comm_equals_dense_comm() {
        // paper §4.1: intermediate factors stay dense, so communication is
        // unchanged by sparsity
        let m = Machine::cpu_cluster();
        let d = predict_rescal_iter(1 << 17, 20, 10, 1024, 1.0, &m);
        let s = predict_rescal_iter(1 << 17, 20, 10, 1024, 1e-5, &m);
        assert!((d.comm() - s.comm()).abs() < 1e-12);
        assert!(s.compute() < d.compute());
    }

    #[test]
    fn k_scaling_roughly_quadratic_in_comm() {
        // §6.3.3: O(k²) trend
        let m = Machine::cpu_cluster();
        let t8 = predict_rescal_iter(1 << 18, 20, 8, 1024, 1.0, &m);
        let t64 = predict_rescal_iter(1 << 18, 20, 64, 1024, 1.0, &m);
        let ratio = t64.total() / t8.total();
        assert!(ratio > 6.0, "k scaling too flat: {ratio}");
    }

    #[test]
    fn clustering_prediction_positive_and_scales() {
        let m = Machine::cpu_cluster();
        let (c1, m1) = predict_clustering(1 << 13, 10, 10, 4, &m, 10);
        let (c2, m2) = predict_clustering(1 << 13, 10, 10, 64, &m, 10);
        assert!(c1 > 0.0 && m1 > 0.0);
        assert!(c2 < c1); // compute shrinks with p
        assert!(m2 > m1); // comm grows with log p
    }
}
