//! Fig 13 replay: model determination in 11.5 TB dense and 9.5 EB sparse
//! tensors.
//!
//! The paper's two flagship runs:
//! * dense 396800 × 396800 × 20 (11.5 TB f32) on 4096 cores (64×64 grid),
//!   k swept 2..11, 10 perturbations, 200 MU updates each — ≈3 h, finds
//!   k = 10 with 6% error and min-silhouette 0.9;
//! * sparse 373555200 × 373555200 × 20 (≈9.5 EB dense-equivalent) on
//!   23 000 cores, densities 1e-5 … 1e-9, 100 MU iterations — >90% of the
//!   time in MPI communication, compute shrinking with density, total time
//!   flat.
//!
//! These scales need 173–963 nodes; here they are *replayed* through the
//! calibrated model (DESIGN.md §3) while `examples/end_to_end.rs` runs the
//! same code path for real at laptop scale.

use super::{predict_clustering, predict_rescal_iter, Machine};

/// One modeled large-scale sweep result.
#[derive(Clone, Debug)]
pub struct ExascaleRun {
    pub label: &'static str,
    pub n: usize,
    pub m: usize,
    pub p: usize,
    pub density: f64,
    pub iters: usize,
    /// (compute seconds, communication seconds) for the whole run.
    pub compute_seconds: f64,
    pub comm_seconds: f64,
}

impl ExascaleRun {
    pub fn total(&self) -> f64 {
        self.compute_seconds + self.comm_seconds
    }

    pub fn comm_fraction(&self) -> f64 {
        self.comm_seconds / self.total().max(1e-30)
    }

    /// Logical tensor size in bytes (f32 dense equivalent).
    pub fn logical_bytes(&self) -> f64 {
        self.n as f64 * self.n as f64 * self.m as f64 * 4.0
    }
}

/// The dense 11.5 TB model-determination run (Fig 13a): full RESCALk sweep
/// k ∈ [2, 11], r perturbations, `iters` MU updates per factorization.
pub fn dense_11tb_run(machine: &Machine) -> ExascaleRun {
    let (n, m, p) = (396_800, 20, 4096);
    let (k_lo, k_hi, r, iters) = (2usize, 11usize, 10usize, 200usize);
    let mut compute = 0.0;
    let mut comm = 0.0;
    for k in k_lo..=k_hi {
        let it = predict_rescal_iter(n, m, k, p, 1.0, machine);
        compute += r as f64 * iters as f64 * it.compute();
        comm += r as f64 * iters as f64 * it.comm();
        let (cc, cm) = predict_clustering(n, k, r, p, machine, 20);
        compute += cc;
        comm += cm;
    }
    ExascaleRun {
        label: "dense 11.5TB RESCALk (k=2..11, r=10, 200 iters)",
        n,
        m,
        p,
        density: 1.0,
        iters,
        compute_seconds: compute,
        comm_seconds: comm,
    }
}

/// The sparse exabyte runs (Fig 13b): 100 MU iterations at each density.
pub fn sparse_exabyte_runs(machine: &Machine) -> Vec<ExascaleRun> {
    let (n, m, k) = (373_555_200, 20, 10);
    // 23 000 cores → nearest square grid 151×151
    let p = 151 * 151;
    let iters = 100;
    [1e-5, 1e-6, 1e-7, 1e-8, 1e-9]
        .iter()
        .map(|&density| {
            let it = predict_rescal_iter(n, m, k, p, density, machine);
            ExascaleRun {
                label: "sparse 9.5EB RESCAL (100 iters)",
                n,
                m,
                p,
                density,
                iters,
                compute_seconds: iters as f64 * it.compute(),
                comm_seconds: iters as f64 * it.comm(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_run_is_hours_scale() {
        // paper: "run for about 3 hours"; accept a broad band (the model is
        // a first-principles estimate, not a fit)
        let run = dense_11tb_run(&Machine::cpu_cluster());
        let hours = run.total() / 3600.0;
        assert!(hours > 0.3 && hours < 30.0, "modeled {hours} h");
        // 11.5 TB logical size
        let tb = run.logical_bytes() / 1e12;
        assert!((tb - 11.5).abs() < 1.5, "logical {tb} TB");
    }

    #[test]
    fn sparse_runs_are_comm_dominated() {
        // paper Fig 13b: >90% of execution time in MPI communication
        for run in sparse_exabyte_runs(&Machine::cpu_cluster()) {
            assert!(
                run.comm_fraction() > 0.85,
                "density {} comm fraction {}",
                run.density,
                run.comm_fraction()
            );
        }
    }

    #[test]
    fn sparse_total_flat_across_density() {
        // total time unaffected by density (communication dominates)
        let runs = sparse_exabyte_runs(&Machine::cpu_cluster());
        let t0 = runs[0].total();
        for run in &runs {
            assert!((run.total() / t0 - 1.0).abs() < 0.15, "total varies: {}", run.total());
        }
    }

    #[test]
    fn sparse_compute_shrinks_with_density() {
        let runs = sparse_exabyte_runs(&Machine::cpu_cluster());
        for w in runs.windows(2) {
            assert!(w[1].compute_seconds <= w[0].compute_seconds * 1.01);
        }
    }

    #[test]
    fn exabyte_logical_size() {
        let runs = sparse_exabyte_runs(&Machine::cpu_cluster());
        let eb = runs[0].logical_bytes() / 1e18;
        assert!(eb > 9.0 && eb < 12.5, "logical {eb} EB");
    }
}
