//! The model-family plane: per-relation MU update rules behind one trait.
//!
//! The distributed loop in [`super::distributed::rescal_rank`] is the
//! same for every model family — tile ownership, the `AᵀA` gram +
//! row-reduce, the per-slice `X_tA` + row-reduce, the final A update and
//! diagonal column broadcast, normalization, convergence checks. What
//! differs per family is the *per-slice* numerator/denominator assembly:
//! which core shape `R_t` has, which GEMMs build the MU terms, and which
//! reconstruction the residual is measured against. [`Model`] captures
//! exactly that seam:
//!
//! * [`Rescal`] — the paper's Gaussian non-negative RESCAL
//!   (`X_t ≈ A R_t Aᵀ`, dense k×k core). Its `slice_update` is the
//!   pre-refactor body of `rescal_rank` moved verbatim, including the
//!   XLA fused-segment fast paths, so `--model rescal` stays
//!   bit-identical to the historical factor digests.
//! * [`DistMult`] — diagonal `R_t` stored as a 1×k row vector
//!   (DGL-KE's production workhorse): ~k× cheaper per-slice updates
//!   because the k×k GEMM chain collapses to column scalings plus one
//!   `rows×k · k×k` product, with the same row/col all-reduce pattern.
//! * [`LogisticRescal`] — Bernoulli likelihood for 0/1 triples (Nickel
//!   & Tresp's Logistic Tensor Factorization): the reconstruction is
//!   `σ(A R_t Aᵀ)` and the MU denominators replace the Gaussian
//!   `A R AᵀA …` chains with products against the sigmoid-activated
//!   reconstruction.
//!
//! Each model owns its slice-level workspace buffers (checked out of
//! the per-rank [`Workspace`] once per job, so the steady-state loop
//! stays allocation-free) and its slice-level collectives — the column
//! reduce of the core numerator and the diagonal row broadcast of the
//! `X_tᵀ…` term. Replication is preserved by construction: every term
//! entering an `R_t` update is all-reduced to the full product on every
//! rank, so the core stays replicated under all three rules.

use crate::backend::{Backend, Workspace};
use crate::comm::grid::RankCtx;
use crate::comm::{CommOp, CommResult, Trace};
use crate::err;
use crate::error::Result;
use crate::tensor::ops::{mu_update, rescale_core};
use crate::tensor::Mat;

use super::distmm::{all_reduce_mat, broadcast_mat};
use super::local::LocalTile;

/// Which model family a factorization runs. Defaults to the paper's
/// Gaussian non-negative RESCAL.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ModelKind {
    /// Gaussian non-negative RESCAL: `X_t ≈ A R_t Aᵀ`, dense k×k core.
    #[default]
    Rescal,
    /// Diagonal core (`R_t = diag(d_t)`, stored 1×k): ~k× cheaper
    /// updates, compact artifacts, elementwise serving.
    DistMult,
    /// Bernoulli likelihood for 0/1 triples: `P(x=1) = σ(A R_t Aᵀ)`.
    Logistic,
}

impl ModelKind {
    /// Stable string form, used on the CLI and in JSON artifacts.
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Rescal => "rescal",
            ModelKind::DistMult => "distmult",
            ModelKind::Logistic => "logistic",
        }
    }

    /// Parse the CLI/JSON string form.
    pub fn parse(s: &str) -> Result<ModelKind> {
        match s {
            "rescal" => Ok(ModelKind::Rescal),
            "distmult" => Ok(ModelKind::DistMult),
            "logistic" => Ok(ModelKind::Logistic),
            other => Err(err!(
                "unknown model family '{other}' (expected rescal, distmult, or logistic)"
            )),
        }
    }

    /// Row count of one core slice `R_t` at rank k: k×k for the dense
    /// families, 1×k for the diagonal one.
    pub fn core_rows(&self, k: usize) -> usize {
        match self {
            ModelKind::DistMult => 1,
            _ => k,
        }
    }

    /// Instantiate the update rule (buffers unacquired until
    /// [`Model::acquire`]).
    pub fn build(&self) -> Box<dyn Model> {
        match self {
            ModelKind::Rescal => Box::new(Rescal::new()),
            ModelKind::DistMult => Box::new(DistMult::new()),
            ModelKind::Logistic => Box::new(LogisticRescal::new()),
        }
    }

    /// Fold the column-normalization scales of A into one core slice:
    /// `R_t ← S R_t S` for the dense families, `d_j ← d_j s_j²` for the
    /// diagonal one (both keep the reconstruction invariant).
    pub fn rescale_core_slice(&self, r_t: &mut Mat, scales: &[f32]) {
        match self {
            ModelKind::DistMult => {
                assert_eq!(r_t.rows(), 1);
                assert_eq!(r_t.cols(), scales.len());
                for (j, &s) in scales.iter().enumerate() {
                    r_t[(0, j)] *= s * s;
                }
            }
            _ => rescale_core(r_t, scales),
        }
    }

    /// Squared Frobenius residual of slice t of the local tile against
    /// this family's reconstruction. Shared by the training convergence
    /// check and the model-selection scorer.
    #[allow(clippy::too_many_arguments)]
    pub fn slice_residual_sq(
        &self,
        tile: &LocalTile,
        t: usize,
        a_row: &Mat,
        r_t: &Mat,
        a_col: &Mat,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) -> f64 {
        match self {
            ModelKind::Rescal => {
                let ar = trace.record(CommOp::MatrixMul, 0, || backend.matmul(a_row, r_t));
                tile.residual_sq(t, &ar, a_col)
            }
            ModelKind::DistMult => {
                let ar = trace.record(CommOp::MatrixMul, 0, || {
                    let mut out = Mat::zeros(a_row.rows(), a_row.cols());
                    scale_cols_into(a_row, r_t.row(0), &mut out);
                    out
                });
                tile.residual_sq(t, &ar, a_col)
            }
            ModelKind::Logistic => {
                let ar = trace.record(CommOp::MatrixMul, 0, || backend.matmul(a_row, r_t));
                tile.residual_sq_sigmoid(t, &ar, a_col)
            }
        }
    }
}

/// One model family's per-slice MU update rule. Implementations own
/// their slice-level workspace buffers and slice-level collectives; the
/// shared loop in `rescal_rank` owns everything slice-independent.
pub trait Model {
    fn kind(&self) -> ModelKind;

    /// Check this model's slice-level temporaries out of the rank's
    /// workspace, once per job, before the MU loop.
    fn acquire(&mut self, ws: &mut Workspace, rows: usize, cols: usize, k: usize);

    /// Return the temporaries to the arena after the loop.
    fn release(&mut self, ws: &mut Workspace);

    /// One slice's MU work: update `r_t` in place (replicated — every
    /// input to the update is all-reduced to the full product first) and
    /// accumulate this slice's numerator/denominator contributions for
    /// the A update. `xa` already holds the row-reduced full `X_t·A`
    /// rows for this rank's row block; `ata` the replicated `AᵀA`.
    #[allow(clippy::too_many_arguments)]
    fn slice_update(
        &mut self,
        ctx: &RankCtx,
        tile: &LocalTile,
        t: usize,
        r_t: &mut Mat,
        a_row: &Mat,
        a_col: &Mat,
        ata: &Mat,
        xa: &Mat,
        num_a: &mut Mat,
        deno_a: &mut Mat,
        eps: f32,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) -> CommResult<()>;
}

/// Numerically stable-enough logistic function for f32 scores.
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// `out[i, j] = src[i, j] * d[j]` — the diagonal-core replacement for a
/// dense `· R_t` GEMM.
pub(crate) fn scale_cols_into(src: &Mat, d: &[f32], out: &mut Mat) {
    let (rows, cols) = src.shape();
    assert_eq!(out.shape(), (rows, cols));
    assert_eq!(d.len(), cols);
    for i in 0..rows {
        let s = src.row(i);
        let o = out.row_mut(i);
        for j in 0..cols {
            o[j] = s[j] * d[j];
        }
    }
}

fn sigmoid_in_place(m: &mut Mat) {
    for v in m.as_mut_slice() {
        *v = sigmoid(*v);
    }
}

fn empty() -> Mat {
    Mat::zeros(0, 0)
}

// ---------------------------------------------------------------------
// Gaussian non-negative RESCAL (the paper's Algorithm 3 slice segment)
// ---------------------------------------------------------------------

/// The paper's Gaussian rule. The buffer set and the `slice_update`
/// body are the pre-refactor `IterBufs`/`rescal_rank` slice segment
/// moved here unchanged — op order, fused-artifact branches, and trace
/// charging included — so this family is bit-identical to the historical
/// implementation.
pub struct Rescal {
    /// `AᵀX_tA` (k×k).
    atxa: Mat,
    /// `R_t·AᵀA` (k×k).
    rata: Mat,
    /// `AᵀA·R_t·AᵀA` (k×k) — the R-update denominator.
    deno_r: Mat,
    /// `X_tA·R_tᵀ` (rows×k).
    xart: Mat,
    /// `A·R_t` (rows×k).
    ar: Mat,
    /// `AᵀA·R_t` (k×k).
    atar: Mat,
    /// `A·R_tᵀ` (rows×k).
    art: Mat,
    /// `A·R_tᵀ·AᵀA·R_t` (rows×k).
    artatar: Mat,
    /// `AᵀA·R_tᵀ` (k×k).
    atart: Mat,
    /// `A·R_t·AᵀA·R_tᵀ` (rows×k).
    aratart: Mat,
    /// `X_tᵀ·AR` partial (cols×k).
    xtar: Mat,
    /// Diagonal-broadcast row block of XᵀAR (rows×k).
    xtar_row: Mat,
}

impl Rescal {
    pub fn new() -> Rescal {
        Rescal {
            atxa: empty(),
            rata: empty(),
            deno_r: empty(),
            xart: empty(),
            ar: empty(),
            atar: empty(),
            art: empty(),
            artatar: empty(),
            atart: empty(),
            aratart: empty(),
            xtar: empty(),
            xtar_row: empty(),
        }
    }
}

impl Default for Rescal {
    fn default() -> Self {
        Rescal::new()
    }
}

impl Model for Rescal {
    fn kind(&self) -> ModelKind {
        ModelKind::Rescal
    }

    fn acquire(&mut self, ws: &mut Workspace, rows: usize, cols: usize, k: usize) {
        self.atxa = ws.acquire(k, k);
        self.rata = ws.acquire(k, k);
        self.deno_r = ws.acquire(k, k);
        self.xart = ws.acquire(rows, k);
        self.ar = ws.acquire(rows, k);
        self.atar = ws.acquire(k, k);
        self.art = ws.acquire(rows, k);
        self.artatar = ws.acquire(rows, k);
        self.atart = ws.acquire(k, k);
        self.aratart = ws.acquire(rows, k);
        self.xtar = ws.acquire(cols, k);
        self.xtar_row = ws.acquire(rows, k);
    }

    fn release(&mut self, ws: &mut Workspace) {
        for m in [
            std::mem::replace(&mut self.atxa, empty()),
            std::mem::replace(&mut self.rata, empty()),
            std::mem::replace(&mut self.deno_r, empty()),
            std::mem::replace(&mut self.xart, empty()),
            std::mem::replace(&mut self.ar, empty()),
            std::mem::replace(&mut self.atar, empty()),
            std::mem::replace(&mut self.art, empty()),
            std::mem::replace(&mut self.artatar, empty()),
            std::mem::replace(&mut self.atart, empty()),
            std::mem::replace(&mut self.aratart, empty()),
            std::mem::replace(&mut self.xtar, empty()),
            std::mem::replace(&mut self.xtar_row, empty()),
        ] {
            ws.release(m);
        }
    }

    fn slice_update(
        &mut self,
        ctx: &RankCtx,
        tile: &LocalTile,
        t: usize,
        r_t: &mut Mat,
        a_row: &Mat,
        _a_col: &Mat,
        ata: &Mat,
        xa: &Mat,
        num_a: &mut Mat,
        deno_a: &mut Mat,
        eps: f32,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) -> CommResult<()> {
        // ---- AᵀXA (line 6) ----
        trace.record(CommOp::MatrixMul, 0, || {
            backend.t_matmul_into(a_row, xa, &mut self.atxa)
        });
        all_reduce_mat(&ctx.col_comm, &mut self.atxa, CommOp::ColumnReduce, trace)?;
        // ---- local slice segment: R update + A-update terms (lines
        // 7-11, 15-19). One fused artifact on the XLA backend (§Perf);
        // composed from write-into ops on the workspace otherwise. ----
        let fused = trace.record(CommOp::MatrixMul, 0, || {
            backend.slice_segment(r_t, ata, &self.atxa, xa, a_row)
        });
        // the fused arm owns its artifact-returned AR; the composed
        // arm writes AR into the workspace buffer — either way the
        // XᵀAR product below reads it without copying
        let fused_ar = match fused {
            Some((r_new, xart, ar, deno)) => {
                *r_t = r_new;
                num_a.add_assign(&xart);
                deno_a.add_assign(&deno);
                Some(ar)
            }
            None => {
                // R update (lines 7-9), possibly via the smaller fused
                // r_update kernel
                let r_fused = trace.record(CommOp::MatrixMul, 0, || {
                    backend.r_update_fused(r_t, ata, &self.atxa)
                });
                match r_fused {
                    Some(new_rt) => *r_t = new_rt,
                    None => {
                        trace.record(CommOp::MatrixMul, 0, || {
                            backend.matmul_into(r_t, ata, &mut self.rata)
                        });
                        trace.record(CommOp::MatrixMul, 0, || {
                            backend.matmul_into(ata, &self.rata, &mut self.deno_r)
                        });
                        mu_update(r_t, &self.atxa, &self.deno_r, eps);
                    }
                }
                // A-update numerator terms (lines 10-11)
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_t_into(xa, r_t, &mut self.xart)
                });
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_into(a_row, r_t, &mut self.ar)
                });
                // A-update denominator (lines 15-20)
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_into(ata, r_t, &mut self.atar)
                });
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_t_into(a_row, r_t, &mut self.art)
                });
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_into(&self.art, &self.atar, &mut self.artatar)
                });
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_t_into(ata, r_t, &mut self.atart)
                });
                trace.record(CommOp::MatrixMul, 0, || {
                    backend.matmul_into(&self.ar, &self.atart, &mut self.aratart)
                });
                num_a.add_assign(&self.xart);
                deno_a.add_assign(&self.artatar);
                deno_a.add_assign(&self.aratart);
                None
            }
        };
        let ar = fused_ar.as_ref().unwrap_or(&self.ar);
        // ---- XᵀAR: tile product + column reduce + diagonal row
        // broadcast (lines 12-13) ----
        tile.xta_into(t, ar, &mut self.xtar, backend, trace);
        all_reduce_mat(&ctx.col_comm, &mut self.xtar, CommOp::ColumnReduce, trace)?;
        // row broadcast from the diagonal rank: member index within the
        // row comm equals the grid column, and the diagonal of row i is
        // at column i. Off-diagonal ranks are pure receivers — the
        // broadcast overwrites their buffer in place.
        if ctx.is_diagonal() {
            self.xtar_row.copy_from(&self.xtar);
        }
        broadcast_mat(&ctx.row_comm, ctx.row, &mut self.xtar_row, CommOp::RowBroadcast, trace)?;
        num_a.add_assign(&self.xtar_row);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// DistMult: diagonal core
// ---------------------------------------------------------------------

/// Diagonal-core rule. With `R_t = diag(d_t)` the Gaussian MU terms
/// collapse: the core numerator is `diag(AᵀX_tA)` (a row-sum of
/// elementwise products, no GEMM), its denominator `d·(G∘G)` for
/// `G = AᵀA`, and every `· R_t` in the A-update becomes a column
/// scaling — one real `rows×k · k×k` GEMM survives per slice.
pub struct DistMult {
    /// `diag(AᵀX_tA)` partial (1×k).
    num_d: Mat,
    /// `d·(G∘G)` (1×k).
    deno_d: Mat,
    /// `G∘G` (k×k).
    gg: Mat,
    /// `G` column-scaled by d (k×k).
    gd: Mat,
    /// `A·D` (rows×k).
    ard: Mat,
    /// `X_tA·D` (rows×k).
    xad: Mat,
    /// `(A·D)(G·D)` (rows×k) — half the A denominator.
    adgd: Mat,
    /// `X_tᵀ·AD` partial (cols×k).
    xtar: Mat,
    /// Diagonal-broadcast row block of Xᵀ·AD (rows×k).
    xtar_row: Mat,
}

impl DistMult {
    pub fn new() -> DistMult {
        DistMult {
            num_d: empty(),
            deno_d: empty(),
            gg: empty(),
            gd: empty(),
            ard: empty(),
            xad: empty(),
            adgd: empty(),
            xtar: empty(),
            xtar_row: empty(),
        }
    }
}

impl Default for DistMult {
    fn default() -> Self {
        DistMult::new()
    }
}

impl Model for DistMult {
    fn kind(&self) -> ModelKind {
        ModelKind::DistMult
    }

    fn acquire(&mut self, ws: &mut Workspace, rows: usize, cols: usize, k: usize) {
        self.num_d = ws.acquire(1, k);
        self.deno_d = ws.acquire(1, k);
        self.gg = ws.acquire(k, k);
        self.gd = ws.acquire(k, k);
        self.ard = ws.acquire(rows, k);
        self.xad = ws.acquire(rows, k);
        self.adgd = ws.acquire(rows, k);
        self.xtar = ws.acquire(cols, k);
        self.xtar_row = ws.acquire(rows, k);
    }

    fn release(&mut self, ws: &mut Workspace) {
        for m in [
            std::mem::replace(&mut self.num_d, empty()),
            std::mem::replace(&mut self.deno_d, empty()),
            std::mem::replace(&mut self.gg, empty()),
            std::mem::replace(&mut self.gd, empty()),
            std::mem::replace(&mut self.ard, empty()),
            std::mem::replace(&mut self.xad, empty()),
            std::mem::replace(&mut self.adgd, empty()),
            std::mem::replace(&mut self.xtar, empty()),
            std::mem::replace(&mut self.xtar_row, empty()),
        ] {
            ws.release(m);
        }
    }

    fn slice_update(
        &mut self,
        ctx: &RankCtx,
        tile: &LocalTile,
        t: usize,
        r_t: &mut Mat,
        a_row: &Mat,
        _a_col: &Mat,
        ata: &Mat,
        xa: &Mat,
        num_a: &mut Mat,
        deno_a: &mut Mat,
        eps: f32,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) -> CommResult<()> {
        let k = r_t.cols();
        // ---- core numerator diag(AᵀX_tA): the j-th entry is
        // Σ_i A_{ij}(X_tA)_{ij} — row blocks sum over the column comm,
        // exactly the AᵀXA reduce pattern of the dense rule ----
        trace.record(CommOp::MatrixMul, 0, || {
            self.num_d.clear();
            for i in 0..a_row.rows() {
                let ai = a_row.row(i);
                let xi = xa.row(i);
                let nd = self.num_d.row_mut(0);
                for j in 0..k {
                    nd[j] += ai[j] * xi[j];
                }
            }
        });
        all_reduce_mat(&ctx.col_comm, &mut self.num_d, CommOp::ColumnReduce, trace)?;
        // ---- core denominator diag(G·D·G) = d·(G∘G): G is replicated,
        // so no collective is needed ----
        trace.record(CommOp::MatrixMul, 0, || {
            self.gg.copy_from(ata);
            self.gg.hadamard_assign(ata);
            backend.matmul_into(r_t, &self.gg, &mut self.deno_d);
        });
        mu_update(r_t, &self.num_d, &self.deno_d, eps);
        // ---- A-update terms under the refreshed d: every `· R_t`
        // collapses to a column scaling ----
        let d: Vec<f32> = r_t.row(0).to_vec();
        trace.record(CommOp::MatrixMul, 0, || {
            scale_cols_into(xa, &d, &mut self.xad);
            scale_cols_into(a_row, &d, &mut self.ard);
            scale_cols_into(ata, &d, &mut self.gd);
        });
        // numerator: X_tA·D
        num_a.add_assign(&self.xad);
        // denominator: A(D G D + D G D) = 2·(A·D)(G·D)
        trace.record(CommOp::MatrixMul, 0, || {
            backend.matmul_into(&self.ard, &self.gd, &mut self.adgd)
        });
        self.adgd.scale(2.0);
        deno_a.add_assign(&self.adgd);
        // ---- numerator term X_tᵀ·AD: tile product + column reduce +
        // diagonal row broadcast, as in the dense rule ----
        tile.xta_into(t, &self.ard, &mut self.xtar, backend, trace);
        all_reduce_mat(&ctx.col_comm, &mut self.xtar, CommOp::ColumnReduce, trace)?;
        if ctx.is_diagonal() {
            self.xtar_row.copy_from(&self.xtar);
        }
        broadcast_mat(&ctx.row_comm, ctx.row, &mut self.xtar_row, CommOp::RowBroadcast, trace)?;
        num_a.add_assign(&self.xtar_row);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Logistic non-negative RESCAL: Bernoulli likelihood
// ---------------------------------------------------------------------

/// Bernoulli rule: the MU numerators keep the Gaussian data terms
/// (`AᵀX_tA`, `X_tA R_tᵀ + X_tᵀA R_t`), while every denominator term
/// replaces `X_t` by the sigmoid reconstruction `S = σ(A R_t Aᵀ)`:
/// `R_t ← R_t ∘ AᵀX_tA / (AᵀS A + ε)` and
/// `A ← A ∘ Σ_t num / Σ_t (S A R_tᵀ + Sᵀ A R_t) + ε`.
/// `S` is materialized per rank as the local `rows×cols` tile of the
/// reconstruction — the same block layout as `X` itself — so the comm
/// pattern matches the Gaussian loop collective-for-collective.
pub struct LogisticRescal {
    /// `AᵀX_tA` (k×k).
    atxa: Mat,
    /// `A·R_t` (rows×k).
    ar: Mat,
    /// Local tile of `σ(A R_t Aᵀ)` (rows×cols).
    s: Mat,
    /// `S·A` row block (rows×k, row-reduced).
    sa: Mat,
    /// `AᵀS A` (k×k).
    atsa: Mat,
    /// `X_tA·R_tᵀ` (rows×k).
    xart: Mat,
    /// `S A·R_tᵀ` (rows×k).
    sart: Mat,
    /// `Sᵀ·AR` partial (cols×k).
    star: Mat,
    /// Diagonal-broadcast row block of Sᵀ·AR (rows×k).
    star_row: Mat,
    /// `X_tᵀ·AR` partial (cols×k).
    xtar: Mat,
    /// Diagonal-broadcast row block of XᵀAR (rows×k).
    xtar_row: Mat,
}

impl LogisticRescal {
    pub fn new() -> LogisticRescal {
        LogisticRescal {
            atxa: empty(),
            ar: empty(),
            s: empty(),
            sa: empty(),
            atsa: empty(),
            xart: empty(),
            sart: empty(),
            star: empty(),
            star_row: empty(),
            xtar: empty(),
            xtar_row: empty(),
        }
    }
}

impl Default for LogisticRescal {
    fn default() -> Self {
        LogisticRescal::new()
    }
}

impl Model for LogisticRescal {
    fn kind(&self) -> ModelKind {
        ModelKind::Logistic
    }

    fn acquire(&mut self, ws: &mut Workspace, rows: usize, cols: usize, k: usize) {
        self.atxa = ws.acquire(k, k);
        self.ar = ws.acquire(rows, k);
        self.s = ws.acquire(rows, cols);
        self.sa = ws.acquire(rows, k);
        self.atsa = ws.acquire(k, k);
        self.xart = ws.acquire(rows, k);
        self.sart = ws.acquire(rows, k);
        self.star = ws.acquire(cols, k);
        self.star_row = ws.acquire(rows, k);
        self.xtar = ws.acquire(cols, k);
        self.xtar_row = ws.acquire(rows, k);
    }

    fn release(&mut self, ws: &mut Workspace) {
        for m in [
            std::mem::replace(&mut self.atxa, empty()),
            std::mem::replace(&mut self.ar, empty()),
            std::mem::replace(&mut self.s, empty()),
            std::mem::replace(&mut self.sa, empty()),
            std::mem::replace(&mut self.atsa, empty()),
            std::mem::replace(&mut self.xart, empty()),
            std::mem::replace(&mut self.sart, empty()),
            std::mem::replace(&mut self.star, empty()),
            std::mem::replace(&mut self.star_row, empty()),
            std::mem::replace(&mut self.xtar, empty()),
            std::mem::replace(&mut self.xtar_row, empty()),
        ] {
            ws.release(m);
        }
    }

    fn slice_update(
        &mut self,
        ctx: &RankCtx,
        tile: &LocalTile,
        t: usize,
        r_t: &mut Mat,
        a_row: &Mat,
        a_col: &Mat,
        _ata: &Mat,
        xa: &Mat,
        num_a: &mut Mat,
        deno_a: &mut Mat,
        eps: f32,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) -> CommResult<()> {
        // ---- core numerator AᵀX_tA (as in the Gaussian rule) ----
        trace.record(CommOp::MatrixMul, 0, || {
            backend.t_matmul_into(a_row, xa, &mut self.atxa)
        });
        all_reduce_mat(&ctx.col_comm, &mut self.atxa, CommOp::ColumnReduce, trace)?;
        // ---- core denominator Aᵀσ(A R_t Aᵀ)A under the *current* R_t:
        // local S tile, S·A (row reduce), AᵀSA (column reduce) ----
        trace.record(CommOp::MatrixMul, 0, || {
            backend.matmul_into(a_row, r_t, &mut self.ar);
            backend.matmul_t_into(&self.ar, a_col, &mut self.s);
            sigmoid_in_place(&mut self.s);
            backend.matmul_into(&self.s, a_col, &mut self.sa);
        });
        all_reduce_mat(&ctx.row_comm, &mut self.sa, CommOp::RowReduce, trace)?;
        trace.record(CommOp::MatrixMul, 0, || {
            backend.t_matmul_into(a_row, &self.sa, &mut self.atsa)
        });
        all_reduce_mat(&ctx.col_comm, &mut self.atsa, CommOp::ColumnReduce, trace)?;
        mu_update(r_t, &self.atxa, &self.atsa, eps);
        // ---- refresh AR, S, and SA under the new R_t for the A terms ----
        trace.record(CommOp::MatrixMul, 0, || {
            backend.matmul_into(a_row, r_t, &mut self.ar);
            backend.matmul_t_into(&self.ar, a_col, &mut self.s);
            sigmoid_in_place(&mut self.s);
            backend.matmul_into(&self.s, a_col, &mut self.sa);
        });
        all_reduce_mat(&ctx.row_comm, &mut self.sa, CommOp::RowReduce, trace)?;
        // ---- A numerator: X_tA·R_tᵀ + X_tᵀ·AR (the Gaussian data
        // terms; the xtar leg keeps the column reduce + diagonal row
        // broadcast) ----
        trace.record(CommOp::MatrixMul, 0, || {
            backend.matmul_t_into(xa, r_t, &mut self.xart)
        });
        num_a.add_assign(&self.xart);
        tile.xta_into(t, &self.ar, &mut self.xtar, backend, trace);
        all_reduce_mat(&ctx.col_comm, &mut self.xtar, CommOp::ColumnReduce, trace)?;
        if ctx.is_diagonal() {
            self.xtar_row.copy_from(&self.xtar);
        }
        broadcast_mat(&ctx.row_comm, ctx.row, &mut self.xtar_row, CommOp::RowBroadcast, trace)?;
        num_a.add_assign(&self.xtar_row);
        // ---- A denominator: S A·R_tᵀ + Sᵀ·AR, mirroring the numerator
        // legs with S in place of X_t ----
        trace.record(CommOp::MatrixMul, 0, || {
            backend.matmul_t_into(&self.sa, r_t, &mut self.sart)
        });
        deno_a.add_assign(&self.sart);
        trace.record(CommOp::MatrixMul, 0, || {
            backend.t_matmul_into(&self.s, &self.ar, &mut self.star)
        });
        all_reduce_mat(&ctx.col_comm, &mut self.star, CommOp::ColumnReduce, trace)?;
        if ctx.is_diagonal() {
            self.star_row.copy_from(&self.star);
        }
        broadcast_mat(&ctx.row_comm, ctx.row, &mut self.star_row, CommOp::RowBroadcast, trace)?;
        deno_a.add_assign(&self.star_row);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_string_roundtrip() {
        for kind in [ModelKind::Rescal, ModelKind::DistMult, ModelKind::Logistic] {
            assert_eq!(ModelKind::parse(kind.as_str()).unwrap(), kind);
        }
        assert!(ModelKind::parse("transe").is_err());
        assert_eq!(ModelKind::default(), ModelKind::Rescal);
    }

    #[test]
    fn core_rows_shapes() {
        assert_eq!(ModelKind::Rescal.core_rows(5), 5);
        assert_eq!(ModelKind::Logistic.core_rows(5), 5);
        assert_eq!(ModelKind::DistMult.core_rows(5), 1);
    }

    #[test]
    fn distmult_rescale_squares_scales() {
        let mut d = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        ModelKind::DistMult.rescale_core_slice(&mut d, &[2.0, 1.0, 0.5]);
        assert_eq!(d.as_slice(), &[4.0, 2.0, 0.75]);
    }

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(10.0) > 0.999);
        assert!(sigmoid(-10.0) < 0.001);
    }

    #[test]
    fn scale_cols_matches_diagonal_matmul() {
        let src = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let d = [2.0, 0.5, 1.0];
        let mut out = Mat::zeros(2, 3);
        scale_cols_into(&src, &d, &mut out);
        // equals src · diag(d)
        let mut diag = Mat::zeros(3, 3);
        for j in 0..3 {
            diag[(j, j)] = d[j];
        }
        let want = src.matmul(&diag);
        assert_eq!(out.as_slice(), want.as_slice());
    }
}
