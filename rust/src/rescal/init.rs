//! Factor initialization: random or NNDSVD (paper §3.4, §6.1.3).

use crate::linalg::nndsvd::nndsvd_init;
use crate::rng::Rng;
use crate::tensor::{Mat, Tensor3};

/// How to initialize A and R.
#[derive(Clone)]
pub enum Init {
    /// U[0,1) entries, seeded.
    Random,
    /// NNDSVD of the concatenated axis-1/axis-2 unfoldings of X for A,
    /// then R bootstrapped by one pass of R updates (paper §6.1.3).
    Nndsvd,
    /// Explicit factors (used to make distributed == sequential tests
    /// bit-comparable).
    Given(Mat, Tensor3),
}

impl Init {
    /// Produce initial (A, R) for a k-rank factorization of `x`.
    pub fn materialize(&self, x: &Tensor3, k: usize, rng: &mut Rng) -> (Mat, Tensor3) {
        let (n, _, m) = x.shape();
        match self {
            Init::Random => {
                let a = Mat::random_uniform(n, k, 0.01, 1.0, rng);
                let r = Tensor3::from_slices(
                    (0..m).map(|_| Mat::random_uniform(k, k, 0.01, 1.0, rng)).collect(),
                );
                (a, r)
            }
            Init::Nndsvd => {
                // concatenated unfoldings along axes 1 and 2: [X_1 … X_m  X_1ᵀ … X_mᵀ]
                let mut concat = Mat::zeros(n, 2 * m * n);
                for t in 0..m {
                    let xt = x.slice(t);
                    for i in 0..n {
                        for j in 0..n {
                            concat[(i, t * n + j)] = xt[(i, j)];
                            concat[(i, (m + t) * n + j)] = xt[(j, i)];
                        }
                    }
                }
                let a = nndsvd_init(&concat, k, 1e-6);
                // bootstrap R with a few multiplicative R-updates at fixed A
                let mut r = Tensor3::from_slices(
                    (0..m).map(|_| Mat::full(k, k, 0.5)).collect(),
                );
                let ata = a.gram();
                for t in 0..m {
                    let xa = x.slice(t).matmul(&a);
                    let atxa = a.t_matmul(&xa);
                    for _ in 0..3 {
                        let rata = r.slice(t).matmul(&ata);
                        let deno = ata.matmul(&rata);
                        crate::tensor::ops::mu_update(
                            r.slice_mut(t),
                            &atxa,
                            &deno,
                            crate::tensor::ops::MU_EPS,
                        );
                    }
                }
                (a, r)
            }
            Init::Given(a, r) => {
                assert_eq!(a.shape(), (n, k), "given A shape");
                assert_eq!(r.shape(), (k, k, m), "given R shape");
                (a.clone(), r.clone())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::is_nonnegative;

    #[test]
    fn random_init_shapes_and_positivity() {
        let mut rng = Rng::new(100);
        let x = Tensor3::random_uniform(10, 10, 3, 0.0, 1.0, &mut rng);
        let (a, r) = Init::Random.materialize(&x, 4, &mut rng);
        assert_eq!(a.shape(), (10, 4));
        assert_eq!(r.shape(), (4, 4, 3));
        assert!(is_nonnegative(&a));
    }

    #[test]
    fn nndsvd_init_shapes_and_positivity() {
        let mut rng = Rng::new(101);
        let x = Tensor3::random_uniform(8, 8, 2, 0.0, 1.0, &mut rng);
        let (a, r) = Init::Nndsvd.materialize(&x, 3, &mut rng);
        assert_eq!(a.shape(), (8, 3));
        assert_eq!(r.shape(), (3, 3, 2));
        assert!(is_nonnegative(&a));
        assert!(is_nonnegative(r.slice(0)));
    }

    #[test]
    fn given_init_passes_through() {
        let mut rng = Rng::new(102);
        let x = Tensor3::random_uniform(6, 6, 2, 0.0, 1.0, &mut rng);
        let a = Mat::full(6, 2, 0.3);
        let r = Tensor3::zeros(2, 2, 2);
        let (a2, r2) = Init::Given(a.clone(), r.clone()).materialize(&x, 2, &mut rng);
        assert_eq!(a2, a);
        assert_eq!(r2, r);
    }
}
