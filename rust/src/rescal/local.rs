//! One rank's tile of the distributed tensor, dense or CSR-sparse.
//!
//! The two products against the tile — `X_t·B` and `X_tᵀ·B` — are the
//! only places X is touched in Algorithm 3, so [`LocalTile`] exposes
//! exactly those, charging `matrix_mul` or `matrix_mul_sparse` in the
//! trace as the paper's breakdown plots do.

use crate::backend::Backend;
use crate::comm::{CommOp, Trace};
use crate::tensor::{kernel, Csr, HalfTensor3, Mat, Tensor3};

/// Per-rank tile: `rows × cols × m` — dense f32, dense 16-bit storage
/// (f16/bf16, widened to f32 on pack inside the GEMM kernel), or sparse.
pub enum LocalTile {
    Dense(Tensor3),
    /// Half-precision dense storage: half the resident bytes and memory
    /// bandwidth of `Dense`; products run through the same f32
    /// microkernel accumulators via the kernel's widen-on-pack entries.
    DenseHalf(HalfTensor3),
    Sparse(Vec<Csr>),
}

impl LocalTile {
    /// Number of relation slices.
    pub fn m(&self) -> usize {
        match self {
            LocalTile::Dense(t) => t.m(),
            LocalTile::DenseHalf(t) => t.m(),
            LocalTile::Sparse(s) => s.len(),
        }
    }

    /// Tile row count (0 for an empty sparse relation list — rejected
    /// upstream by `JobData::validate`, but never a panic here: a worker
    /// panic poisons the whole rank pool).
    pub fn rows(&self) -> usize {
        match self {
            LocalTile::Dense(t) => t.n1(),
            LocalTile::DenseHalf(t) => t.n1(),
            LocalTile::Sparse(s) => s.first().map_or(0, |c| c.rows()),
        }
    }

    /// Tile column count.
    pub fn cols(&self) -> usize {
        match self {
            LocalTile::Dense(t) => t.n2(),
            LocalTile::DenseHalf(t) => t.n2(),
            LocalTile::Sparse(s) => s.first().map_or(0, |c| c.cols()),
        }
    }

    /// Approximate resident memory of this tile, for the engine's
    /// per-dataset accounting (dense: f32 per cell; half: 2 bytes per
    /// cell; sparse: CSR storage including any transpose cache built so
    /// far — note the engine samples this at load time, before the first
    /// sparse job can build those caches).
    pub fn resident_bytes(&self) -> usize {
        match self {
            LocalTile::Dense(t) => t.n1() * t.n2() * t.m() * 4,
            LocalTile::DenseHalf(t) => t.n1() * t.n2() * t.m() * 2,
            LocalTile::Sparse(s) => s.iter().map(|c| c.resident_bytes()).sum(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, LocalTile::Sparse(_))
    }

    /// `X_t · B` (rows×k) written into `out`, traced as dense or sparse
    /// matmul. `out` comes from the caller's workspace — the hot loop
    /// reuses one buffer across every slice and iteration.
    pub fn xa_into(
        &self,
        t: usize,
        b: &Mat,
        out: &mut Mat,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) {
        match self {
            LocalTile::Dense(x) => {
                let bytes = x.n1() * x.n2() * 4;
                trace.record(CommOp::MatrixMul, bytes, || backend.matmul_into(x.slice(t), b, out))
            }
            LocalTile::DenseHalf(x) => {
                // half the bytes of the f32 branch move through memory;
                // the kernel widens on pack, so accumulation stays f32
                let bytes = x.n1() * x.n2() * 2;
                trace.record(CommOp::MatrixMul, bytes, || {
                    kernel::gemm_nn_half_into(x.slice(t), b, out, false)
                })
            }
            LocalTile::Sparse(s) => {
                let bytes = s[t].nnz() * 8;
                trace.record(CommOp::MatrixMulSparse, bytes, || s[t].matmul_dense_into(b, out))
            }
        }
    }

    /// `X_tᵀ · B` (cols×k) written into `out`.
    pub fn xta_into(
        &self,
        t: usize,
        b: &Mat,
        out: &mut Mat,
        backend: &mut dyn Backend,
        trace: &mut Trace,
    ) {
        match self {
            LocalTile::Dense(x) => {
                let bytes = x.n1() * x.n2() * 4;
                trace
                    .record(CommOp::MatrixMul, bytes, || backend.t_matmul_into(x.slice(t), b, out))
            }
            LocalTile::DenseHalf(x) => {
                let bytes = x.n1() * x.n2() * 2;
                trace.record(CommOp::MatrixMul, bytes, || {
                    kernel::gemm_tn_half_into(x.slice(t), b, out)
                })
            }
            LocalTile::Sparse(s) => {
                let bytes = s[t].nnz() * 8;
                trace.record(CommOp::MatrixMulSparse, bytes, || s[t].t_matmul_dense_into(b, out))
            }
        }
    }

    /// `X_t · B` (rows×k), allocating — compat shim over
    /// [`LocalTile::xa_into`].
    pub fn xa(&self, t: usize, b: &Mat, backend: &mut dyn Backend, trace: &mut Trace) -> Mat {
        let mut out = Mat::zeros(self.rows(), b.cols());
        self.xa_into(t, b, &mut out, backend, trace);
        out
    }

    /// `X_tᵀ · B` (cols×k), allocating — compat shim over
    /// [`LocalTile::xta_into`].
    pub fn xta(&self, t: usize, b: &Mat, backend: &mut dyn Backend, trace: &mut Trace) -> Mat {
        let mut out = Mat::zeros(self.cols(), b.cols());
        self.xta_into(t, b, &mut out, backend, trace);
        out
    }

    /// Squared Frobenius norm of the local tile.
    pub fn norm_sq(&self) -> f64 {
        match self {
            LocalTile::Dense(x) => {
                let n = x.norm_fro() as f64;
                n * n
            }
            LocalTile::DenseHalf(x) => x.slices().iter().map(|s| s.sum_sq()).sum(),
            LocalTile::Sparse(s) => s
                .iter()
                .map(|c| {
                    let n = c.norm_fro() as f64;
                    n * n
                })
                .sum(),
        }
    }

    /// Squared Frobenius norm of `X_t − A_row · R_t · A_colᵀ` for slice t.
    /// `ar` is the precomputed `A_row · R_t`.
    pub fn residual_sq(&self, t: usize, ar: &Mat, a_col: &Mat) -> f64 {
        let rec = ar.matmul_t(a_col); // rows × cols
        self.residual_sq_against(t, &rec)
    }

    /// Squared Frobenius norm of `X_t − σ(A_row · R_t · A_colᵀ)` — the
    /// logistic family's Brier-style reconstruction residual.
    pub fn residual_sq_sigmoid(&self, t: usize, ar: &Mat, a_col: &Mat) -> f64 {
        let mut rec = ar.matmul_t(a_col); // rows × cols
        for v in rec.as_mut_slice() {
            *v = crate::rescal::model::sigmoid(*v);
        }
        self.residual_sq_against(t, &rec)
    }

    fn residual_sq_against(&self, t: usize, rec: &Mat) -> f64 {
        match self {
            LocalTile::Dense(x) => {
                let xt = x.slice(t);
                let mut acc = 0.0f64;
                for (a, b) in xt.as_slice().iter().zip(rec.as_slice()) {
                    let d = (*a - *b) as f64;
                    acc += d * d;
                }
                acc
            }
            LocalTile::DenseHalf(x) => {
                let xt = x.slice(t);
                let (rows, cols) = xt.shape();
                let mut acc = 0.0f64;
                for i in 0..rows {
                    for j in 0..cols {
                        let d = (xt.at(i, j) - rec[(i, j)]) as f64;
                        acc += d * d;
                    }
                }
                acc
            }
            LocalTile::Sparse(s) => {
                // ‖X − Rec‖² over the dense reconstruction: Σ rec² over
                // all cells, then patch the stored entries by walking the
                // CSR row pointers directly — the tile is never
                // densified (it used to be, per slice × iteration ×
                // perturbation).
                let xt = &s[t];
                let mut acc: f64 =
                    rec.as_slice().iter().map(|&v| (v as f64) * (v as f64)).sum();
                for i in 0..xt.rows() {
                    let (cols, vals) = xt.row_entries(i);
                    for (&j, &x) in cols.iter().zip(vals) {
                        let r = rec[(i, j)];
                        acc += ((x - r) as f64).powi(2) - (r as f64).powi(2);
                    }
                }
                acc
            }
        }
    }

    /// Perturbed copy: every (stored) element multiplied by U[1−δ, 1+δ]
    /// (Algorithm 4; sparse branch perturbs nonzeros only).
    pub fn perturb(&self, delta: f32, rng: &mut crate::rng::Rng) -> LocalTile {
        match self {
            LocalTile::Dense(x) => {
                let mut out = x.clone();
                for t in 0..out.m() {
                    for v in out.slice_mut(t).as_mut_slice() {
                        *v *= rng.uniform_range(1.0 - delta, 1.0 + delta);
                    }
                }
                LocalTile::Dense(out)
            }
            LocalTile::DenseHalf(x) => {
                let mut out = x.clone();
                for t in 0..out.m() {
                    out.slice_mut(t)
                        .map_in_place(|v| v * rng.uniform_range(1.0 - delta, 1.0 + delta));
                }
                LocalTile::DenseHalf(out)
            }
            LocalTile::Sparse(s) => {
                LocalTile::Sparse(s.iter().map(|c| c.perturb(delta, rng)).collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    #[test]
    fn dense_xa_xta() {
        let mut rng = Rng::new(110);
        let x = Tensor3::random_uniform(8, 6, 2, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(6, 3, 0.0, 1.0, &mut rng);
        let b2 = Mat::random_uniform(8, 3, 0.0, 1.0, &mut rng);
        let tile = LocalTile::Dense(x.clone());
        let mut be = NativeBackend::new();
        let mut tr = Trace::new();
        let got = tile.xa(1, &b, &mut be, &mut tr);
        assert_close(got.as_slice(), x.slice(1).matmul(&b).as_slice(), 1e-5);
        let got_t = tile.xta(0, &b2, &mut be, &mut tr);
        assert_close(got_t.as_slice(), x.slice(0).t_matmul(&b2).as_slice(), 1e-5);
        assert!(tr.seconds(CommOp::MatrixMul) >= 0.0);
        assert_eq!(tr.events().len(), 2); // one event each for xa and xta
    }

    #[test]
    fn sparse_matches_dense_products() {
        let mut rng = Rng::new(111);
        let s: Vec<Csr> = (0..2).map(|_| Csr::random(10, 7, 0.3, &mut rng)).collect();
        let dense = Tensor3::from_slices(s.iter().map(|c| c.to_dense()).collect());
        let b = Mat::random_uniform(7, 4, 0.0, 1.0, &mut rng);
        let bt = Mat::random_uniform(10, 4, 0.0, 1.0, &mut rng);
        let st = LocalTile::Sparse(s);
        let dt = LocalTile::Dense(dense);
        let mut be = NativeBackend::new();
        let mut tr = Trace::new();
        for t in 0..2 {
            assert_close(
                st.xa(t, &b, &mut be, &mut tr).as_slice(),
                dt.xa(t, &b, &mut be, &mut tr).as_slice(),
                1e-4,
            );
            assert_close(
                st.xta(t, &bt, &mut be, &mut tr).as_slice(),
                dt.xta(t, &bt, &mut be, &mut tr).as_slice(),
                1e-4,
            );
        }
        assert!(tr.bytes(CommOp::MatrixMulSparse) > 0);
    }

    #[test]
    fn half_tile_matches_widened_dense_tile_bitwise() {
        use crate::tensor::DType;
        let mut rng = Rng::new(115);
        let x = Tensor3::random_uniform(9, 7, 2, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(7, 3, 0.0, 1.0, &mut rng);
        let b2 = Mat::random_uniform(9, 3, 0.0, 1.0, &mut rng);
        let mut be = NativeBackend::new();
        let mut tr = Trace::new();
        for dtype in [DType::F16, DType::Bf16] {
            let hx = HalfTensor3::from_tensor3(&x, dtype);
            let widened = LocalTile::Dense(hx.to_f32());
            let half = LocalTile::DenseHalf(hx);
            // widen-on-pack: identical arithmetic to widening up front
            for t in 0..2 {
                assert_eq!(
                    half.xa(t, &b, &mut be, &mut tr).as_slice(),
                    widened.xa(t, &b, &mut be, &mut tr).as_slice(),
                    "{dtype:?} xa slice {t}"
                );
                assert_eq!(
                    half.xta(t, &b2, &mut be, &mut tr).as_slice(),
                    widened.xta(t, &b2, &mut be, &mut tr).as_slice(),
                    "{dtype:?} xta slice {t}"
                );
            }
            assert_eq!(half.resident_bytes() * 2, widened.resident_bytes());
            assert!((half.norm_sq() - widened.norm_sq()).abs() < 1e-6 * widened.norm_sq());
            let ar = Mat::random_uniform(9, 2, 0.0, 1.0, &mut rng);
            let ac = Mat::random_uniform(7, 2, 0.0, 1.0, &mut rng);
            let (rh, rw) = (half.residual_sq(0, &ar, &ac), widened.residual_sq(0, &ar, &ac));
            assert!((rh - rw).abs() < 1e-6 * rw.max(1.0), "half {rh} vs widened {rw}");
        }
    }

    #[test]
    fn residual_sq_dense_vs_sparse() {
        let mut rng = Rng::new(112);
        let s = vec![Csr::random(6, 6, 0.4, &mut rng)];
        let dense = Tensor3::from_slices(vec![s[0].to_dense()]);
        let a_row = Mat::random_uniform(6, 2, 0.0, 1.0, &mut rng);
        let a_col = Mat::random_uniform(6, 2, 0.0, 1.0, &mut rng);
        let r = Mat::random_uniform(2, 2, 0.0, 1.0, &mut rng);
        let ar = a_row.matmul(&r);
        let d = LocalTile::Dense(dense).residual_sq(0, &ar, &a_col);
        let sp = LocalTile::Sparse(s).residual_sq(0, &ar, &a_col);
        assert!((d - sp).abs() < 1e-3 * d.max(1.0), "dense {d} vs sparse {sp}");
    }

    #[test]
    fn resident_bytes_tracks_storage() {
        let mut rng = Rng::new(114);
        let dense = LocalTile::Dense(Tensor3::random_uniform(8, 6, 2, 0.0, 1.0, &mut rng));
        assert_eq!(dense.resident_bytes(), 8 * 6 * 2 * 4);
        let c = Csr::random(8, 8, 0.25, &mut rng);
        let nnz = c.nnz();
        let sparse = LocalTile::Sparse(vec![c]);
        let w = std::mem::size_of::<usize>();
        assert_eq!(sparse.resident_bytes(), nnz * (4 + w) + 9 * w);
        // defensive shape accessors on an empty relation list
        let empty = LocalTile::Sparse(vec![]);
        assert_eq!((empty.rows(), empty.cols(), empty.m()), (0, 0, 0));
        assert_eq!(empty.resident_bytes(), 0);
    }

    #[test]
    fn perturb_bounds_dense() {
        let mut rng = Rng::new(113);
        let x = Tensor3::random_uniform(5, 5, 2, 0.5, 1.0, &mut rng);
        let tile = LocalTile::Dense(x.clone());
        let p = tile.perturb(0.02, &mut rng);
        if let LocalTile::Dense(px) = p {
            for t in 0..2 {
                for (a, b) in x.slice(t).as_slice().iter().zip(px.slice(t).as_slice()) {
                    let ratio = b / a;
                    assert!(ratio >= 0.98 - 1e-5 && ratio <= 1.02 + 1e-5);
                }
            }
        } else {
            panic!("expected dense");
        }
    }
}
