//! Algorithm 3: distributed non-negative RESCAL on the 2D virtual grid.
//!
//! Rank (i, j) holds the tile `X^(i,j)` (entity rows block i × entity cols
//! block j, all m relation slices), the row-block factors `A^(i)` and
//! `A^(j)` (equal on the diagonal), and a replicated core `R`. One MU
//! iteration interleaves local GEMMs with exactly the collectives of the
//! paper:
//!
//! * `AᵀA`    — local gram of A^(j), all_reduce over the **row** comm
//! * `X_tA`   — local tile product, all_reduce over the **row** comm
//! * `AᵀX_tA` — local product, all_reduce over the **column** comm
//! * `X_tᵀAR` — local product, all_reduce over the **column** comm, then
//!              **broadcast along rows from the diagonal rank** so each
//!              rank gets its own row block (Alg 3 line 13)
//! * refreshed `A^(j)` — **broadcast along columns from the diagonal**
//!              (Alg 3 line 23)
//!
//! All ranks of a row compute bit-identical `A^(i)` updates because the
//! all_reduce is order-deterministic (see `comm::group`).

use std::sync::Arc;

use super::distmm::{all_reduce_mat, broadcast_mat};
use super::local::LocalTile;
use super::model::{Model, ModelKind};
use super::RescalOptions;
use crate::backend::{Backend, Workspace, WorkspaceStats};
use crate::comm::grid::RankCtx;
use crate::comm::{CommOp, CommResult, Trace};
use crate::rng::Rng;
use crate::tensor::ops::mu_update;
use crate::tensor::{Mat, Tensor3};

/// Distributed factor initialization.
#[derive(Clone)]
pub enum DistInit {
    /// Seeded random, consistent across ranks: row block b of A is drawn
    /// from a stream keyed by (seed, b); R from (seed, "r"). No
    /// communication needed.
    Random { seed: u64 },
    /// Slice blocks out of explicit full factors (test parity with the
    /// sequential oracle).
    Given(Arc<Mat>, Arc<Tensor3>),
}

impl DistInit {
    /// Materialize this rank's (A_row, A_col, R). The model family
    /// decides the core slice shape: k×k for the dense families, 1×k
    /// for DistMult.
    fn materialize(
        &self,
        ctx: &RankCtx,
        n: usize,
        k: usize,
        m: usize,
        model: ModelKind,
    ) -> (Mat, Mat, Tensor3) {
        match self {
            DistInit::Random { seed } => {
                let block = |b: usize| {
                    let (s, e) = ctx.grid.chunk(n, b);
                    let mut rng = Rng::for_rank(*seed, b, 1);
                    Mat::random_uniform(e - s, k, 0.01, 1.0, &mut rng)
                };
                let a_row = block(ctx.row);
                let a_col = block(ctx.col);
                let mut rng_r = Rng::for_rank(*seed, usize::MAX, 2);
                let core_rows = model.core_rows(k);
                let r = Tensor3::from_slices(
                    (0..m)
                        .map(|_| Mat::random_uniform(core_rows, k, 0.01, 1.0, &mut rng_r))
                        .collect(),
                );
                (a_row, a_col, r)
            }
            DistInit::Given(a, r) => {
                assert_eq!(a.shape(), (n, k));
                assert_eq!(
                    (r.n1(), r.n2()),
                    (model.core_rows(k), k),
                    "given core slices do not match the model family's shape"
                );
                let block = |b: usize| {
                    let (s, e) = ctx.grid.chunk(n, b);
                    Mat::from_fn(e - s, k, |i, j| a[(s + i, j)])
                };
                (block(ctx.row), block(ctx.col), (**r).clone())
            }
        }
    }
}

/// Per-rank configuration for one distributed factorization.
pub struct DistRescalConfig {
    pub opts: RescalOptions,
    pub init: DistInit,
    /// Global entity count n (tiles are blocks of an n×n×m tensor).
    pub n: usize,
    /// Which update rule runs in the slice segment (see
    /// [`super::model`]).
    pub model: ModelKind,
}

/// What each rank returns.
pub struct RankResult {
    /// This rank's row block of the final A (replicated across its row).
    pub a_row: Mat,
    /// Replicated final core tensor.
    pub r: Tensor3,
    /// Final relative reconstruction error (identical on all ranks).
    pub rel_error: f32,
    pub iters_run: usize,
    /// Workspace checkout counters for this job (delta, not cumulative):
    /// `mat_allocs` is 0 on a warm rank — every temporary was arena
    /// reuse.
    pub workspace: WorkspaceStats,
}

/// Run distributed RESCAL on this rank's tile. All ranks must call this
/// with consistent arguments; collectives keep them in lockstep.
///
/// Fallible: on a multi-process transport a dead or timed-out peer
/// surfaces here as a typed [`crate::comm::CommError`], which the pool
/// rolls back as a job error.
///
/// `ws` is the rank's persistent workspace arena: every iteration
/// temporary is checked out of it once before the MU loop, so the loop
/// itself performs zero heap allocations — and on a warm rank (second
/// job onward) even the checkouts are reuses, which
/// [`RankResult::workspace`] counter-asserts.
pub fn rescal_rank(
    ctx: &RankCtx,
    tile: &LocalTile,
    cfg: &DistRescalConfig,
    backend: &mut dyn Backend,
    ws: &mut Workspace,
    trace: &mut Trace,
) -> CommResult<RankResult> {
    let n = cfg.n;
    let k = cfg.opts.k;
    let m = tile.m();
    let eps = cfg.opts.eps;
    let ws_before = ws.stats();
    let mut model = cfg.model.build();
    let (mut a_row, mut a_col, mut r) = cfg.init.materialize(ctx, n, k, m, cfg.model);
    assert_eq!(a_row.rows(), tile.rows(), "A_row/tile row mismatch");
    assert_eq!(a_col.rows(), tile.cols(), "A_col/tile col mismatch");

    // ‖X‖² once, for relative error
    let mut norm_buf = Mat::from_vec(1, 1, vec![tile.norm_sq() as f32]);
    ctx.world.all_reduce_sum(norm_buf.as_mut_slice())?;
    let x_norm_sq = norm_buf[(0, 0)] as f64;

    // The slice-independent temporaries live here; the model family owns
    // its slice-level ones. Everything is checked out of the per-rank
    // [`Workspace`] **once** — the MU loop itself performs zero workspace
    // checkouts, so steady-state iterations are allocation-free (and on a
    // warm rank even these checkouts are arena reuses, which
    // [`RankResult::workspace`] proves).
    let rows = a_row.rows();
    let cols = a_col.rows();
    let mut ata = ws.acquire(k, k);
    let mut xa = ws.acquire(rows, k);
    let mut num_a = ws.acquire(rows, k);
    let mut deno_a = ws.acquire(rows, k);
    model.acquire(ws, rows, cols, k);

    // Each iteration segment is bracketed with a `"phase"` timeline
    // span (pack / reduce / gemm / mu_update / normalize); the op-level
    // spans recorded inside nest under them in the exported trace. The
    // gemm phase is labelled with the dispatched microkernel variant
    // (e.g. `gemm[avx2_fma_8x8]`), so a trace pins down which SIMD path
    // produced its timings.
    let mut iters_run = 0;
    let mut last_err = f32::NAN;
    for iter in 0..cfg.opts.max_iters {
        iters_run = iter + 1;
        trace.set_iter(iter as u32);
        // ---- AᵀA, replicated (Alg 3 line 3) ----
        let ph = trace.phase_start();
        trace.record(CommOp::GramMul, a_col.as_slice().len() * 4, || {
            backend.gram_into(&a_col, &mut ata)
        });
        trace.phase_end("pack", ph);
        let ph = trace.phase_start();
        all_reduce_mat(&ctx.row_comm, &mut ata, CommOp::RowReduce, trace)?;
        trace.phase_end("reduce", ph);

        num_a.clear();
        deno_a.clear();
        let ph = trace.phase_start();
        for t in 0..m {
            // ---- XA (Alg 3 line 5) ----
            tile.xa_into(t, &a_col, &mut xa, backend, trace);
            all_reduce_mat(&ctx.row_comm, &mut xa, CommOp::RowReduce, trace)?;
            // ---- the model family's slice segment: R_t update +
            // A-update numerator/denominator contributions (Alg 3 lines
            // 6-19 for the Gaussian rule) ----
            model.slice_update(
                ctx,
                tile,
                t,
                r.slice_mut(t),
                &a_row,
                &a_col,
                &ata,
                &xa,
                &mut num_a,
                &mut deno_a,
                eps,
                backend,
                trace,
            )?;
        }
        trace.phase_end(crate::tensor::kernel::dispatch::active().gemm_label, ph);
        // ---- A update (line 22) ----
        let ph = trace.phase_start();
        mu_update(&mut a_row, &num_a, &deno_a, eps);
        trace.phase_end("mu_update", ph);
        // ---- refresh A^(j): column broadcast from the diagonal (line 23) ----
        let ph = trace.phase_start();
        if ctx.is_diagonal() {
            a_col.copy_from(&a_row);
        }
        broadcast_mat(&ctx.col_comm, ctx.col, &mut a_col, CommOp::ColumnBroadcast, trace)?;
        trace.phase_end("normalize", ph);

        // optional convergence check
        let mut err_fresh = false;
        if cfg.opts.err_every > 0 && (iter + 1) % cfg.opts.err_every == 0 {
            last_err = distributed_rel_error(
                ctx, tile, &a_row, &a_col, &r, x_norm_sq, cfg.model, backend, trace,
            )?;
            err_fresh = true;
        }
        // Streaming telemetry flush + leader progress event. A
        // collective over the world group, so it runs on every rank
        // before the (rank-uniform) tol break below; no-op when the
        // recorder is off.
        trace.iteration_boundary(&ctx.world, iter as u32, last_err, err_fresh)?;
        if err_fresh && cfg.opts.tol > 0.0 && last_err < cfg.opts.tol {
            break;
        }
    }
    trace.set_iter(crate::obs::NO_ITER);
    model.release(ws);
    for buf in [ata, xa, num_a, deno_a] {
        ws.release(buf);
    }

    // ---- final normalization: global column norms via column all_reduce ----
    let ph = trace.phase_start();
    let mut sq = Mat::from_vec(
        1,
        k,
        {
            let mut acc = vec![0.0f32; k];
            for i in 0..a_row.rows() {
                let row = a_row.row(i);
                for (j, &v) in row.iter().enumerate() {
                    acc[j] += v * v;
                }
            }
            acc
        },
    );
    all_reduce_mat(&ctx.col_comm, &mut sq, CommOp::ColumnReduce, trace)?;
    let scales: Vec<f32> = sq.as_slice().iter().map(|&s| if s > 0.0 { s.sqrt() } else { 1.0 }).collect();
    for i in 0..a_row.rows() {
        let row = a_row.row_mut(i);
        for j in 0..k {
            row[j] /= scales[j];
        }
    }
    for t in 0..m {
        cfg.model.rescale_core_slice(r.slice_mut(t), &scales);
    }
    // refresh a_col one last time for the error evaluation
    if ctx.is_diagonal() {
        a_col.copy_from(&a_row);
    }
    broadcast_mat(&ctx.col_comm, ctx.col, &mut a_col, CommOp::ColumnBroadcast, trace)?;
    trace.phase_end("normalize", ph);
    let rel = distributed_rel_error(
        ctx, tile, &a_row, &a_col, &r, x_norm_sq, cfg.model, backend, trace,
    )?;
    Ok(RankResult {
        a_row,
        r,
        rel_error: rel,
        iters_run,
        workspace: ws.stats().since(ws_before),
    })
}

/// ‖X − X̂‖_F / ‖X‖_F against the model family's reconstruction X̂,
/// computed from the local tiles (identical on all ranks after the world
/// all_reduce).
#[allow(clippy::too_many_arguments)]
fn distributed_rel_error(
    ctx: &RankCtx,
    tile: &LocalTile,
    a_row: &Mat,
    a_col: &Mat,
    r: &Tensor3,
    x_norm_sq: f64,
    model: ModelKind,
    backend: &mut dyn Backend,
    trace: &mut Trace,
) -> CommResult<f32> {
    let mut local = 0.0f64;
    for t in 0..tile.m() {
        local += model.slice_residual_sq(tile, t, a_row, r.slice(t), a_col, backend, trace);
    }
    let mut buf = Mat::from_vec(1, 1, vec![local as f32]);
    all_reduce_mat(&ctx.world, &mut buf, CommOp::RowReduce, trace)?;
    Ok(((buf[(0, 0)] as f64).max(0.0).sqrt() / x_norm_sq.max(1e-300).sqrt()) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::comm::grid::run_on_grid;
    use crate::data::synthetic;
    use crate::rescal::{rescal_seq, Init};
    use crate::testing::assert_close;

    /// Scatter a dense tensor into per-rank tiles and run the distributed
    /// algorithm; gather A from the diagonal.
    fn run_dist(
        x: &Tensor3,
        p: usize,
        opts: RescalOptions,
        init: DistInit,
    ) -> (Mat, Tensor3, f32) {
        let n = x.n1();
        let results = run_on_grid(p, |ctx| {
            let (r0, r1) = ctx.grid.chunk(n, ctx.row);
            let (c0, c1) = ctx.grid.chunk(n, ctx.col);
            let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
            let cfg = DistRescalConfig {
                opts: opts.clone(),
                init: init.clone(),
                n,
                model: ModelKind::Rescal,
            };
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::disabled();
            let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescal_rank");
            (ctx.row, ctx.col, out)
        });
        // gather A blocks from the diagonal ranks
        let grid = crate::comm::Grid::new(p);
        let k = opts.k;
        let mut a = Mat::zeros(n, k);
        let mut r = None;
        let mut err = 0.0;
        for (row, col, res) in results {
            if row == col {
                let (s, _e) = grid.chunk(n, row);
                for i in 0..res.a_row.rows() {
                    for j in 0..k {
                        a[(s + i, j)] = res.a_row[(i, j)];
                    }
                }
                err = res.rel_error;
                r = Some(res.r);
            }
        }
        (a, r.unwrap(), err)
    }

    #[test]
    fn p1_matches_sequential_exactly() {
        let planted = synthetic::planted_tensor(12, 2, 3, 0.0, 200);
        let x = planted.x;
        let mut rng = Rng::new(7);
        let (a0, r0) = Init::Random.materialize(&x, 3, &mut rng);
        let opts = RescalOptions::new(3, 20);
        let seq = rescal_seq(&x, &opts, Init::Given(a0.clone(), r0.clone()), 0);
        let (a, r, err) = run_dist(
            &x,
            1,
            opts,
            DistInit::Given(Arc::new(a0), Arc::new(r0)),
        );
        assert_close(a.as_slice(), seq.a.as_slice(), 1e-4);
        for t in 0..2 {
            assert_close(r.slice(t).as_slice(), seq.r.slice(t).as_slice(), 1e-3);
        }
        assert!((err - seq.rel_error).abs() < 1e-4);
    }

    #[test]
    fn p4_matches_sequential() {
        let planted = synthetic::planted_tensor(16, 2, 3, 0.0, 201);
        let x = planted.x;
        let mut rng = Rng::new(8);
        let (a0, r0) = Init::Random.materialize(&x, 3, &mut rng);
        let opts = RescalOptions::new(3, 15);
        let seq = rescal_seq(&x, &opts, Init::Given(a0.clone(), r0.clone()), 0);
        let (a, r, err) =
            run_dist(&x, 4, opts, DistInit::Given(Arc::new(a0), Arc::new(r0)));
        assert_close(a.as_slice(), seq.a.as_slice(), 1e-3);
        for t in 0..2 {
            assert_close(r.slice(t).as_slice(), seq.r.slice(t).as_slice(), 1e-2);
        }
        assert!((err - seq.rel_error).abs() < 1e-3);
    }

    #[test]
    fn p9_uneven_chunks_match_sequential() {
        // n = 14 over q = 3 -> chunks 5,5,4: exercises the ragged path
        let planted = synthetic::planted_tensor(14, 2, 2, 0.0, 202);
        let x = planted.x;
        let mut rng = Rng::new(9);
        let (a0, r0) = Init::Random.materialize(&x, 2, &mut rng);
        let opts = RescalOptions::new(2, 10);
        let seq = rescal_seq(&x, &opts, Init::Given(a0.clone(), r0.clone()), 0);
        let (a, _r, _e) =
            run_dist(&x, 9, opts, DistInit::Given(Arc::new(a0), Arc::new(r0)));
        assert_close(a.as_slice(), seq.a.as_slice(), 1e-3);
    }

    #[test]
    fn random_init_converges_distributed() {
        let planted = synthetic::planted_tensor(24, 3, 3, 0.0, 203);
        let (_a, _r, err) = run_dist(
            &planted.x,
            4,
            RescalOptions::new(3, 200),
            DistInit::Random { seed: 42 },
        );
        assert!(err < 0.06, "rel_error={err}");
    }

    #[test]
    fn sparse_tiles_match_dense_run() {
        // identical data through the CSR path and the dense path must give
        // the same factorization
        let xs = synthetic::sparse_planted(24, 2, 3, 0.25, 204);
        let dense = Tensor3::from_slices(xs.iter().map(|s| s.to_dense()).collect());
        let n = 24;
        let p = 4;
        let opts = RescalOptions::new(3, 40);
        let run = |sparse: bool| {
            run_on_grid(p, |ctx| {
                let (r0, r1) = ctx.grid.chunk(n, ctx.row);
                let (c0, c1) = ctx.grid.chunk(n, ctx.col);
                let tile = if sparse {
                    LocalTile::Sparse(xs.iter().map(|s| s.tile(r0, r1, c0, c1)).collect())
                } else {
                    LocalTile::Dense(dense.tile(r0, r1, c0, c1))
                };
                let cfg = DistRescalConfig {
                    opts: opts.clone(),
                    init: DistInit::Random { seed: 5 },
                    n,
                    model: ModelKind::Rescal,
                };
                let mut backend = NativeBackend::new();
                let mut ws = Workspace::new();
                let mut trace = Trace::new();
                let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                    .expect("in-process rescal_rank");
                (out, trace.bytes(CommOp::MatrixMulSparse))
            })
        };
        let sparse_results = run(true);
        let dense_results = run(false);
        for ((s, sparse_bytes), (d, _)) in sparse_results.iter().zip(&dense_results) {
            assert!((s.rel_error - d.rel_error).abs() < 1e-3);
            assert_close(s.a_row.as_slice(), d.a_row.as_slice(), 1e-2);
            assert!(*sparse_bytes > 0, "sparse path not exercised");
        }
    }

    #[test]
    fn timeline_records_phase_spans_per_iteration() {
        let planted = synthetic::planted_tensor(12, 2, 2, 0.0, 206);
        let x = planted.x;
        let iters = 3;
        let results = run_on_grid(4, |ctx| {
            let (r0, r1) = ctx.grid.chunk(12, ctx.row);
            let (c0, c1) = ctx.grid.chunk(12, ctx.col);
            let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
            let cfg = DistRescalConfig {
                opts: RescalOptions::new(2, iters),
                init: DistInit::Random { seed: 1 },
                n: 12,
                model: ModelKind::Rescal,
            };
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::new();
            rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescal_rank");
            trace.timeline_snapshot(ctx.world.rank)
        });
        for tl in results {
            // the gemm phase label carries the dispatched microkernel
            // variant, e.g. `gemm[avx2_fma_8x8]` — match by prefix
            for label in ["pack", "reduce", "gemm", "mu_update", "normalize"] {
                let count = tl
                    .spans
                    .iter()
                    .filter(|s| s.cat == "phase" && s.label.starts_with(label))
                    .count();
                assert!(count >= iters, "phase {label} appeared {count} times");
            }
            let gemm_label = crate::tensor::kernel::dispatch::active().gemm_label;
            assert!(
                tl.spans.iter().any(|s| s.cat == "phase" && s.label == gemm_label),
                "gemm phase must carry the dispatched variant ({gemm_label})"
            );
            // comm spans carry the real wire traffic
            assert!(tl.spans.iter().any(|s| s.cat == "comm" && s.bytes > 0));
            // spans are stamped with the iteration they belong to
            assert!(tl.spans.iter().any(|s| s.iter == (iters - 1) as u32));
            assert!(tl.spans.iter().any(|s| s.iter == crate::obs::NO_ITER));
        }
    }

    #[test]
    fn trace_has_all_collective_categories() {
        let planted = synthetic::planted_tensor(12, 2, 2, 0.0, 205);
        let x = planted.x;
        let results = run_on_grid(4, |ctx| {
            let (r0, r1) = ctx.grid.chunk(12, ctx.row);
            let (c0, c1) = ctx.grid.chunk(12, ctx.col);
            let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
            let cfg = DistRescalConfig {
                opts: RescalOptions::new(2, 3),
                init: DistInit::Random { seed: 1 },
                n: 12,
                model: ModelKind::Rescal,
            };
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::new();
            rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescal_rank");
            trace
        });
        for trace in results {
            for op in [
                CommOp::GramMul,
                CommOp::MatrixMul,
                CommOp::RowReduce,
                CommOp::ColumnReduce,
                CommOp::RowBroadcast,
                CommOp::ColumnBroadcast,
            ] {
                assert!(
                    trace.events().iter().any(|e| e.op == op),
                    "missing op {:?}",
                    op
                );
            }
        }
    }
}
