//! Sequential non-negative RESCAL (Equation 2 of the paper), the
//! single-process oracle the distributed implementation is tested against.

use super::{Init, RescalOptions};
use crate::rng::Rng;
use crate::tensor::ops::{mu_update, normalize_cols, rescale_core};
use crate::tensor::{Mat, Tensor3};

/// Result of a sequential factorization.
pub struct SeqRescal {
    pub a: Mat,
    pub r: Tensor3,
    pub rel_error: f32,
    pub iters_run: usize,
}

/// Plain Equation-2 multiplicative updates on a full tensor.
///
/// Per iteration:
/// `R_t ← R_t ∘ AᵀX_tA / (AᵀA R_t AᵀA + ε)` for each t, then
/// `A ← A ∘ Σ_t(X_tAR_tᵀ + X_tᵀAR_t) / Σ_t A(R_tAᵀAR_tᵀ + R_tᵀAᵀAR_t) + ε`.
pub fn rescal_seq(x: &Tensor3, opts: &RescalOptions, init: Init, seed: u64) -> SeqRescal {
    let (n, n2, m) = x.shape();
    assert_eq!(n, n2, "RESCAL needs a square entity tensor");
    let k = opts.k;
    let (mut a, mut r) = init.materialize(x, k, &mut Rng::new(seed));
    let mut iters_run = 0;
    for iter in 0..opts.max_iters {
        iters_run = iter + 1;
        let ata = a.gram();
        // accumulate A-update terms across slices
        let mut num_a = Mat::zeros(n, k);
        let mut deno_a = Mat::zeros(n, k);
        for t in 0..m {
            let xt = x.slice(t);
            let xa = xt.matmul(&a);
            // ---- R update (Eq 2, first rule) ----
            let atxa = a.t_matmul(&xa);
            let rata = r.slice(t).matmul(&ata);
            let deno_r = ata.matmul(&rata); // AᵀA · R_t · AᵀA
            let num_r = atxa;
            mu_update(r.slice_mut(t), &num_r, &deno_r, opts.eps);
            // ---- A-update terms with the refreshed R_t (Alg 3 order) ----
            let rt = r.slice(t);
            // numerator: X_t A R_tᵀ + X_tᵀ A R_t
            let xart = xa.matmul_t(rt);
            let ar = a.matmul(rt);
            let xtar = xt.t_matmul(&ar);
            num_a.add_assign(&xart);
            num_a.add_assign(&xtar);
            // denominator: A (R_t AᵀA R_tᵀ + R_tᵀ AᵀA R_t)
            let atar = ata.matmul(rt); // AᵀA R_t
            let art = a.matmul_t(rt); // A R_tᵀ
            let artatar = art.matmul(&atar); // A R_tᵀ AᵀA R_t
            let atart = ata.matmul_t(rt); // AᵀA R_tᵀ
            let aratart = ar.matmul(&atart); // A R_t AᵀA R_tᵀ
            deno_a.add_assign(&artatar);
            deno_a.add_assign(&aratart);
        }
        mu_update(&mut a, &num_a, &deno_a, opts.eps);
        if opts.err_every > 0 && opts.tol > 0.0 && (iter + 1) % opts.err_every == 0 {
            let e = x.rel_error(&a, &r);
            if e < opts.tol {
                break;
            }
        }
    }
    // final normalization: ‖A_i‖ = 1 with inverse scaling folded into R
    let scales = normalize_cols(&mut a);
    for t in 0..m {
        rescale_core(r.slice_mut(t), &scales);
    }
    let rel_error = x.rel_error(&a, &r);
    SeqRescal { a, r, rel_error, iters_run }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tensor::ops::{col_norms, is_nonnegative};

    fn planted(n: usize, m: usize, k: usize, seed: u64) -> Tensor3 {
        synthetic::planted_tensor(n, m, k, 0.0, seed).x
    }

    #[test]
    fn error_decreases_monotonically_in_practice() {
        let x = planted(24, 3, 3, 1);
        let mut prev = f32::INFINITY;
        for iters in [5usize, 20, 60] {
            let out = rescal_seq(&x, &RescalOptions::new(3, iters), Init::Random, 7);
            assert!(
                out.rel_error <= prev + 1e-4,
                "error rose: {prev} -> {}",
                out.rel_error
            );
            prev = out.rel_error;
        }
    }

    #[test]
    fn fits_planted_low_rank_tensor() {
        let x = planted(32, 4, 3, 2);
        let out = rescal_seq(&x, &RescalOptions::new(3, 300), Init::Random, 3);
        assert!(out.rel_error < 0.05, "rel_error={}", out.rel_error);
    }

    #[test]
    fn factors_stay_nonnegative() {
        let x = planted(20, 3, 4, 4);
        let out = rescal_seq(&x, &RescalOptions::new(4, 50), Init::Random, 5);
        assert!(is_nonnegative(&out.a));
        for t in 0..3 {
            assert!(is_nonnegative(out.r.slice(t)));
        }
    }

    #[test]
    fn columns_are_normalized() {
        let x = planted(20, 2, 3, 6);
        let out = rescal_seq(&x, &RescalOptions::new(3, 30), Init::Random, 7);
        for norm in col_norms(&out.a) {
            assert!((norm - 1.0).abs() < 1e-4, "col norm {norm}");
        }
    }

    #[test]
    fn nndsvd_init_converges_faster_than_random() {
        let x = planted(32, 3, 4, 8);
        let iters = 25;
        let rnd = rescal_seq(&x, &RescalOptions::new(4, iters), Init::Random, 9);
        let svd = rescal_seq(&x, &RescalOptions::new(4, iters), Init::Nndsvd, 9);
        // NNDSVD should do no worse (paper §3.4: faster convergence)
        assert!(
            svd.rel_error <= rnd.rel_error * 1.25,
            "nndsvd {} vs random {}",
            svd.rel_error,
            rnd.rel_error
        );
    }

    #[test]
    fn early_stop_respects_tolerance() {
        let x = planted(24, 2, 3, 10);
        let opts = RescalOptions::new(3, 500).with_tol(0.10, 5);
        let out = rescal_seq(&x, &opts, Init::Random, 11);
        assert!(out.iters_run < 500, "should stop early, ran {}", out.iters_run);
        assert!(out.rel_error < 0.10 + 0.02);
    }

    #[test]
    fn asymmetric_relations_are_captured() {
        // directed structure: community 0 points to community 1 only
        let mut a_true = Mat::zeros(12, 2);
        for i in 0..6 {
            a_true[(i, 0)] = 1.0;
            a_true[(i + 6, 1)] = 1.0;
        }
        let mut r_true = Mat::zeros(2, 2);
        r_true[(0, 1)] = 1.0; // asymmetric
        let xt = a_true.matmul(&r_true).matmul_t(&a_true);
        let x = Tensor3::from_slices(vec![xt]);
        let out = rescal_seq(&x, &RescalOptions::new(2, 400), Init::Random, 12);
        assert!(out.rel_error < 0.05, "rel_error={}", out.rel_error);
        // recovered R slice should be asymmetric in the same direction
        let r = out.r.slice(0);
        let fwd = r[(0, 1)].max(r[(1, 0)]);
        let bwd = r[(0, 1)].min(r[(1, 0)]);
        assert!(fwd > 5.0 * bwd.max(1e-6), "directionality lost: {:?}", r.as_slice());
    }
}
