//! Sequential non-negative RESCAL (Equation 2 of the paper), the
//! single-process oracle the distributed implementation is tested
//! against.
//!
//! Since the model-family refactor this is no longer a second copy of
//! the MU rules: it *is* the distributed algorithm instantiated on a
//! 1×1 grid (one rank, every collective a self-loop), driven through
//! the same [`Model`](super::model::Model) slice updates. The reference
//! and distributed math cannot drift because they are the same code.

use std::sync::Arc;

use super::distributed::{rescal_rank, DistInit, DistRescalConfig};
use super::local::LocalTile;
use super::model::ModelKind;
use super::{Init, RescalOptions};
use crate::backend::native::NativeBackend;
use crate::backend::Workspace;
use crate::comm::grid::run_on_grid;
use crate::comm::Trace;
use crate::rng::Rng;
use crate::tensor::{Mat, Tensor3};

/// Result of a sequential factorization.
pub struct SeqRescal {
    pub a: Mat,
    pub r: Tensor3,
    pub rel_error: f32,
    pub iters_run: usize,
}

/// Equation-2 multiplicative updates on a full tensor: the 1×1-grid
/// instantiation of [`rescal_rank`] with the Gaussian
/// [`ModelKind::Rescal`] rule.
///
/// Per iteration:
/// `R_t ← R_t ∘ AᵀX_tA / (AᵀA R_t AᵀA + ε)` for each t, then
/// `A ← A ∘ Σ_t(X_tAR_tᵀ + X_tᵀAR_t) / Σ_t A(R_tAᵀAR_tᵀ + R_tᵀAᵀAR_t) + ε`.
pub fn rescal_seq(x: &Tensor3, opts: &RescalOptions, init: Init, seed: u64) -> SeqRescal {
    let (n, n2, _m) = x.shape();
    assert_eq!(n, n2, "RESCAL needs a square entity tensor");
    // materialize the full factors once (Random/NNDSVD/Given), then hand
    // them to the grid as explicit initial factors
    let (a0, r0) = init.materialize(x, opts.k, &mut Rng::new(seed));
    let cfg = DistRescalConfig {
        opts: opts.clone(),
        init: DistInit::Given(Arc::new(a0), Arc::new(r0)),
        n,
        model: ModelKind::Rescal,
    };
    let mut results = run_on_grid(1, |ctx| {
        let tile = LocalTile::Dense(x.clone());
        let mut backend = NativeBackend::new();
        let mut ws = Workspace::new();
        let mut trace = Trace::disabled();
        rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
            .expect("a 1×1 in-process grid cannot hit transport errors")
    });
    let res = results.pop().expect("one rank on a 1×1 grid");
    SeqRescal { a: res.a_row, r: res.r, rel_error: res.rel_error, iters_run: res.iters_run }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tensor::ops::{col_norms, is_nonnegative};

    fn planted(n: usize, m: usize, k: usize, seed: u64) -> Tensor3 {
        synthetic::planted_tensor(n, m, k, 0.0, seed).x
    }

    #[test]
    fn error_decreases_monotonically_in_practice() {
        let x = planted(24, 3, 3, 1);
        let mut prev = f32::INFINITY;
        for iters in [5usize, 20, 60] {
            let out = rescal_seq(&x, &RescalOptions::new(3, iters), Init::Random, 7);
            assert!(
                out.rel_error <= prev + 1e-4,
                "error rose: {prev} -> {}",
                out.rel_error
            );
            prev = out.rel_error;
        }
    }

    #[test]
    fn fits_planted_low_rank_tensor() {
        let x = planted(32, 4, 3, 2);
        let out = rescal_seq(&x, &RescalOptions::new(3, 300), Init::Random, 3);
        assert!(out.rel_error < 0.05, "rel_error={}", out.rel_error);
    }

    #[test]
    fn factors_stay_nonnegative() {
        let x = planted(20, 3, 4, 4);
        let out = rescal_seq(&x, &RescalOptions::new(4, 50), Init::Random, 5);
        assert!(is_nonnegative(&out.a));
        for t in 0..3 {
            assert!(is_nonnegative(out.r.slice(t)));
        }
    }

    #[test]
    fn columns_are_normalized() {
        let x = planted(20, 2, 3, 6);
        let out = rescal_seq(&x, &RescalOptions::new(3, 30), Init::Random, 7);
        for norm in col_norms(&out.a) {
            assert!((norm - 1.0).abs() < 1e-4, "col norm {norm}");
        }
    }

    #[test]
    fn nndsvd_init_converges_faster_than_random() {
        let x = planted(32, 3, 4, 8);
        let iters = 25;
        let rnd = rescal_seq(&x, &RescalOptions::new(4, iters), Init::Random, 9);
        let svd = rescal_seq(&x, &RescalOptions::new(4, iters), Init::Nndsvd, 9);
        // NNDSVD should do no worse (paper §3.4: faster convergence)
        assert!(
            svd.rel_error <= rnd.rel_error * 1.25,
            "nndsvd {} vs random {}",
            svd.rel_error,
            rnd.rel_error
        );
    }

    #[test]
    fn early_stop_respects_tolerance() {
        let x = planted(24, 2, 3, 10);
        let opts = RescalOptions::new(3, 500).with_tol(0.10, 5);
        let out = rescal_seq(&x, &opts, Init::Random, 11);
        assert!(out.iters_run < 500, "should stop early, ran {}", out.iters_run);
        assert!(out.rel_error < 0.10 + 0.02);
    }

    #[test]
    fn asymmetric_relations_are_captured() {
        // directed structure: community 0 points to community 1 only
        let mut a_true = Mat::zeros(12, 2);
        for i in 0..6 {
            a_true[(i, 0)] = 1.0;
            a_true[(i + 6, 1)] = 1.0;
        }
        let mut r_true = Mat::zeros(2, 2);
        r_true[(0, 1)] = 1.0; // asymmetric
        let xt = a_true.matmul(&r_true).matmul_t(&a_true);
        let x = Tensor3::from_slices(vec![xt]);
        let out = rescal_seq(&x, &RescalOptions::new(2, 400), Init::Random, 12);
        assert!(out.rel_error < 0.05, "rel_error={}", out.rel_error);
        // recovered R slice should be asymmetric in the same direction
        let r = out.r.slice(0);
        let fwd = r[(0, 1)].max(r[(1, 0)]);
        let bwd = r[(0, 1)].min(r[(1, 0)]);
        assert!(fwd > 5.0 * bwd.max(1e-6), "directionality lost: {:?}", r.as_slice());
    }
}
