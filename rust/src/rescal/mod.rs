//! Non-negative RESCAL: sequential reference and the distributed
//! 2D-grid multiplicative-update algorithm (paper Algorithms 2 & 3),
//! with the per-slice MU rule pluggable per model family ([`model`]).

pub mod distributed;
pub mod distmm;
pub mod init;
pub mod local;
pub mod model;
pub mod seq;

pub use distributed::{rescal_rank, DistRescalConfig, RankResult};
pub use init::Init;
pub use local::LocalTile;
pub use model::{Model, ModelKind};
pub use seq::{rescal_seq, SeqRescal};

/// Shared convergence / iteration settings.
#[derive(Clone, Debug)]
pub struct RescalOptions {
    /// Number of latent communities.
    pub k: usize,
    /// Maximum MU iterations.
    pub max_iters: usize,
    /// Stop when relative error drops below this (checked every
    /// `err_every` iterations; 0 disables early stopping).
    pub tol: f32,
    /// How often to evaluate the reconstruction error (it costs extra
    /// GEMMs). 0 = never during iterations (only at the end).
    pub err_every: usize,
    /// ε in the MU denominators.
    pub eps: f32,
}

impl RescalOptions {
    pub fn new(k: usize, max_iters: usize) -> Self {
        RescalOptions { k, max_iters, tol: 0.0, err_every: 0, eps: crate::tensor::ops::MU_EPS }
    }

    pub fn with_tol(mut self, tol: f32, err_every: usize) -> Self {
        self.tol = tol;
        self.err_every = err_every;
        self
    }
}
