//! Algorithm 2: distributed matrix multiplication along a sub-communicator.
//!
//! `distMM(A_local, B_local, comm)` = all_reduce_sum over `comm` of the
//! local products — each member holds one block of the summed inner
//! dimension, so the reduced result is the full product, replicated on
//! every member of the group.
//!
//! All helpers are fallible (the group may sit on a TCP transport whose
//! peers can die) and charge the trace with the wire bytes the transport
//! actually moved.

use crate::comm::{CommOp, CommResult, Group, Trace};
use crate::tensor::Mat;

/// All-reduce a matrix over a group, charging `op` in the trace. The
/// matrix is replaced by the elementwise sum across members.
pub fn all_reduce_mat(
    group: &Group,
    m: &mut Mat,
    op: CommOp,
    trace: &mut Trace,
) -> CommResult<()> {
    trace.record_comm(op, group, || group.all_reduce_sum(m.as_mut_slice()))
}

/// Broadcast a matrix from group-local `root`, charging `op`.
pub fn broadcast_mat(
    group: &Group,
    root: usize,
    m: &mut Mat,
    op: CommOp,
    trace: &mut Trace,
) -> CommResult<()> {
    trace.record_comm(op, group, || group.broadcast(root, m.as_mut_slice()))
}

/// distMM: sum the local partial product over `group`. `partial` is this
/// member's `A_local · B_local`; on return it holds the full product.
pub fn dist_mm(group: &Group, partial: Mat, op: CommOp, trace: &mut Trace) -> CommResult<Mat> {
    let mut out = partial;
    all_reduce_mat(group, &mut out, op, trace)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::grid::run_on_grid;
    use crate::rng::Rng;
    use crate::tensor::Mat;
    use crate::testing::assert_close;

    /// Full AᵀB computed distributedly over 1D column blocks must equal
    /// the sequential product.
    #[test]
    fn distmm_matches_sequential() {
        let mut rng = Rng::new(120);
        let n = 12;
        let k = 3;
        let a = Mat::random_uniform(n, k, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(n, k, 0.0, 1.0, &mut rng);
        let want = a.t_matmul(&b); // k×k
        let p = 4; // 2x2 grid; row comm has 2 members
        let results = run_on_grid(p, |ctx| {
            // block along rows: member j of the row comm holds rows chunk j
            let (s, e) = ctx.grid.chunk(n, ctx.col);
            let a_blk = Mat::from_fn(e - s, k, |i, j| a[(s + i, j)]);
            let b_blk = Mat::from_fn(e - s, k, |i, j| b[(s + i, j)]);
            let mut trace = Trace::new();
            let partial = a_blk.t_matmul(&b_blk);
            let full = dist_mm(&ctx.row_comm, partial, CommOp::RowReduce, &mut trace)
                .expect("in-process dist_mm");
            (full, trace)
        });
        for (full, trace) in results {
            assert_close(full.as_slice(), want.as_slice(), 1e-4);
            assert!(trace.bytes(CommOp::RowReduce) > 0);
        }
    }

    #[test]
    fn broadcast_mat_replicates() {
        let results = run_on_grid(4, |ctx| {
            let mut m = if ctx.row_comm.rank == 0 {
                Mat::full(2, 2, ctx.row as f32 + 1.0)
            } else {
                Mat::zeros(2, 2)
            };
            let mut trace = Trace::new();
            broadcast_mat(&ctx.row_comm, 0, &mut m, CommOp::RowBroadcast, &mut trace)
                .expect("in-process broadcast");
            m
        });
        for (rank, m) in results.iter().enumerate() {
            let row = rank / 2;
            assert_eq!(m.as_slice(), &[row as f32 + 1.0; 4][..]);
        }
    }
}
