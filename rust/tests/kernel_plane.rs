//! Kernel-plane integration tests: packed-microkernel parity across
//! adversarial shapes and transpose variants, legacy-kernel agreement,
//! and the counter-asserted zero-allocation steady state of the
//! workspace-backed training hot loop.

use drescal::backend::native::NativeBackend;
use drescal::backend::{Backend, Workspace};
use drescal::comm::grid::run_on_grid;
use drescal::comm::Trace;
use drescal::data::synthetic::{self, SyntheticSpec};
use drescal::engine::{Engine, EngineConfig, Report};
use drescal::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use drescal::rescal::{LocalTile, ModelKind, RescalOptions};
use drescal::rng::Rng;
use drescal::tensor::dense::{gemm, gemm_legacy};
use drescal::tensor::kernel::dispatch;
use drescal::tensor::{kernel, DType, HalfMat, Mat};
use drescal::testing::{assert_close, naive_gemm as naive};

/// Shapes straddling the microkernel (MR/NR), blocking (MC/KC/NC), and
/// threading boundaries, plus degenerate vectors.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 300, 1),   // 1×n · n×1
    (300, 1, 5),   // m×1 outer-product-ish
    (5, 1, 300),
    (7, 9, 11),    // nothing divides MR/NR
    (8, 8, 8),     // exactly one microkernel tile
    (9, 257, 17),  // KC straddle with ragged edges
    (64, 64, 64),
    (65, 129, 127),
    (130, 40, 200),
];

#[test]
fn backend_variants_match_naive_reference_across_shapes() {
    let mut rng = Rng::new(900);
    let mut be = NativeBackend::new();
    for &(m, k, n) in SHAPES {
        let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
        let want = naive(m, k, n, |i, p| a[(i, p)], |p, j| b[(p, j)]);

        // NN via the backend into-API
        let mut c = Mat::zeros(m, n);
        be.matmul_into(&a, &b, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // TN: Aᵀ·B with A stored k-major
        let at = a.transpose();
        let mut c = Mat::zeros(m, n);
        be.t_matmul_into(&at, &b, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // NT: A·Bᵀ with B stored n×k
        let bt = b.transpose();
        let mut c = Mat::zeros(m, n);
        be.matmul_t_into(&a, &bt, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // TT via the kernel entry point (no Backend method needs it yet)
        let mut c = Mat::zeros(m, n);
        kernel::gemm_tt_into(&at, &bt, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // gram: AᵀA, exactly symmetric
        let mut g = Mat::zeros(k, k);
        be.gram_into(&a, &mut g);
        let want_g = naive(k, m, k, |i, p| a[(p, i)], |p, j| a[(p, j)]);
        assert_close(g.as_slice(), want_g.as_slice(), 2e-3);
        for i in 0..k {
            for j in 0..k {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }
}

#[test]
fn packed_and_legacy_kernels_agree_serial_and_threaded() {
    let mut rng = Rng::new(901);
    // small stays serial; the large ones cross the 2^20 FMA threshold on
    // multi-core hosts and take the threaded macro-panel path
    for &(m, k, n) in &[(6, 10, 4), (150, 120, 110), (300, 130, 90)] {
        let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
        let mut packed = Mat::zeros(m, n);
        gemm(&a, &b, &mut packed, false);
        let mut legacy = Mat::zeros(m, n);
        gemm_legacy(&a, &b, &mut legacy, false);
        assert_close(packed.as_slice(), legacy.as_slice(), 2e-3);
        let want = naive(m, k, n, |i, p| a[(i, p)], |p, j| b[(p, j)]);
        assert_close(packed.as_slice(), want.as_slice(), 2e-3);
    }
}

#[test]
fn high_level_mat_ops_ride_the_packed_kernel() {
    let mut rng = Rng::new(902);
    let a = Mat::random_uniform(33, 21, -1.0, 1.0, &mut rng);
    let b = Mat::random_uniform(21, 19, -1.0, 1.0, &mut rng);
    let want = naive(33, 21, 19, |i, p| a[(i, p)], |p, j| b[(p, j)]);
    assert_close(a.matmul(&b).as_slice(), want.as_slice(), 1e-3);
    assert_close(
        a.transpose().t_matmul(&b).as_slice(),
        want.as_slice(),
        1e-3,
    );
    assert_close(a.matmul_t(&b.transpose()).as_slice(), want.as_slice(), 1e-3);
    assert_close(a.gram().as_slice(), a.t_matmul(&a).as_slice(), 1e-3);
}

/// The zero-allocation guarantee inside one job: every iteration
/// temporary is checked out before the MU loop, so the workspace alloc
/// count is independent of how many iterations run.
#[test]
fn factorize_allocs_are_independent_of_iteration_count() {
    let x = synthetic::planted_tensor(16, 2, 3, 0.0, 903).x;
    let run = |iters: usize| {
        let results = run_on_grid(1, |ctx| {
            let tile = LocalTile::Dense(x.clone());
            let cfg = DistRescalConfig {
                opts: RescalOptions::new(3, iters),
                init: DistInit::Random { seed: 4 },
                n: 16,
                model: ModelKind::Rescal,
            };
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::disabled();
            rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescal_rank")
                .workspace
        });
        results[0]
    };
    let one = run(1);
    let many = run(12);
    assert!(one.mat_allocs > 0, "cold workspace must allocate the iteration buffers");
    assert_eq!(
        one.mat_allocs, many.mat_allocs,
        "12 iterations must allocate exactly what 1 iteration does — \
         all subsequent iterations are buffer reuse"
    );
}

/// The zero-allocation guarantee across jobs: the engine's rank pool
/// keeps each rank's workspace alive, so a repeated factorize job
/// performs zero workspace allocations — every checkout is arena reuse.
#[test]
fn warm_pool_factorize_performs_zero_workspace_allocations() {
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let data = engine.load_dataset(SyntheticSpec::dense(24, 2, 3, 7)).unwrap();
    let opts = RescalOptions::new(3, 6);
    let cold = engine.factorize(data, &opts, 42).unwrap();
    assert!(cold.workspace.mat_allocs > 0, "cold ranks must populate their arenas");
    let warm = engine.factorize(data, &opts, 42).unwrap();
    assert_eq!(
        warm.workspace.mat_allocs, 0,
        "a warm rank pool must serve every iteration temporary from reuse"
    );
    assert_eq!(
        warm.workspace.mat_reuses, cold.workspace.mat_allocs + cold.workspace.mat_reuses,
        "warm job checks out exactly the buffers the cold job allocated"
    );
    // results are identical — the arena changes where buffers live, not
    // what the algorithm computes
    assert_close(warm.a.as_slice(), cold.a.as_slice(), 1e-6);
    assert_eq!(warm.rel_error, cold.rel_error);
}

#[test]
fn report_json_carries_workspace_counters() {
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let data = engine.load_dataset(SyntheticSpec::dense(12, 2, 2, 5)).unwrap();
    let report = engine.factorize(data, &RescalOptions::new(2, 3), 1).unwrap();
    let ws = report.workspace;
    let json = Report::Factorize(report).to_json();
    let back = Report::from_json(&json).unwrap();
    match back {
        Report::Factorize(r) => assert_eq!(r.workspace, ws),
        _ => panic!("kind changed in roundtrip"),
    }
    // archived pre-kernel-plane reports (no workspace field) still parse
    let legacy = drescal::json::Json::parse(
        r#"{"kind":"simulate","scenario":"s","runs":[]}"#,
    )
    .unwrap();
    assert!(Report::from_json(&legacy).is_ok());
}

/// The sparse residual accumulator walks CSR structure directly; it must
/// agree with the dense residual on identical data (and never densify).
#[test]
fn sparse_residual_matches_dense_on_shared_data() {
    let mut rng = Rng::new(905);
    let s = vec![drescal::tensor::Csr::random(40, 40, 0.15, &mut rng)];
    let dense = drescal::tensor::Tensor3::from_slices(vec![s[0].to_dense()]);
    let a_row = Mat::random_uniform(40, 3, 0.0, 1.0, &mut rng);
    let a_col = Mat::random_uniform(40, 3, 0.0, 1.0, &mut rng);
    let r = Mat::random_uniform(3, 3, 0.0, 1.0, &mut rng);
    let ar = a_row.matmul(&r);
    let d = LocalTile::Dense(dense).residual_sq(0, &ar, &a_col);
    let sp = LocalTile::Sparse(s).residual_sq(0, &ar, &a_col);
    assert!((d - sp).abs() < 1e-3 * d.max(1.0), "dense {d} vs sparse {sp}");
}

/// Every SIMD variant the host supports must be **bitwise** equal to the
/// portable scalar reference: the scalar tile uses `mul_add` (one
/// rounding per FMA, same as the vector units), SIMD vectorizes only the
/// independent j-lanes, and zero-padded edge lanes are FMA no-ops — so
/// there is no shape, ragged edge, or KC straddle where they may differ.
#[test]
fn simd_variants_match_scalar_bit_for_bit_across_shape_grid() {
    let variants = dispatch::variants();
    let scalar = variants[0];
    assert_eq!(scalar.name, "scalar_8x8");
    let mut rng = Rng::new(910);
    // every ragged edge 1..MR × 1..NR (and past NR=16 for avx512f_8x16),
    // plus k spanning the KC=256 blocking boundary
    let ms: Vec<usize> = (1usize..=9).chain([16, 65]).collect();
    let ns: Vec<usize> = (1usize..=17).chain([33]).collect();
    let ks = [1usize, 7, 255, 256, 257];
    for &m in &ms {
        for &n in &ns {
            for &k in &ks {
                let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
                let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
                let mut want = Mat::zeros(m, n);
                kernel::gemm_nn_into_with(scalar, &a, &b, &mut want, false);
                for &kern in &variants[1..] {
                    let mut got = Mat::zeros(m, n);
                    kernel::gemm_nn_into_with(kern, &a, &b, &mut got, false);
                    assert_eq!(
                        got.as_slice(),
                        want.as_slice(),
                        "{} != scalar at {m}x{k}x{n}",
                        kern.name
                    );
                }
            }
        }
    }
    // transpose packing paths and the accumulate flag on one adversarial
    // shape (ragged in every dimension, k straddles KC)
    let (m, k, n) = (13, 257, 11);
    let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
    let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
    let at = a.transpose();
    let bt = b.transpose();
    let seed = Mat::random_uniform(m, n, -1.0, 1.0, &mut rng);
    type TransposeRun = fn(&'static dispatch::KernelDesc, &Mat, &Mat, &mut Mat);
    for &kern in &variants[1..] {
        for (tag, run) in
            [("tn", dyn_tn as TransposeRun), ("nt", dyn_nt), ("tt", dyn_tt)]
        {
            let (lhs, rhs) = match tag {
                "tn" => (&at, &b),
                "nt" => (&a, &bt),
                _ => (&at, &bt),
            };
            let mut want = Mat::zeros(m, n);
            run(scalar, lhs, rhs, &mut want);
            let mut got = Mat::zeros(m, n);
            run(kern, lhs, rhs, &mut got);
            assert_eq!(got.as_slice(), want.as_slice(), "{} {tag} != scalar", kern.name);
        }
        // accumulate=true adds onto identical seeds → still bitwise
        let mut want = seed.clone();
        kernel::gemm_nn_into_with(scalar, &a, &b, &mut want, true);
        let mut got = seed.clone();
        kernel::gemm_nn_into_with(kern, &a, &b, &mut got, true);
        assert_eq!(got.as_slice(), want.as_slice(), "{} accumulate != scalar", kern.name);
    }
}

fn dyn_tn(kern: &'static dispatch::KernelDesc, a: &Mat, b: &Mat, c: &mut Mat) {
    kernel::gemm_tn_into_with(kern, a, b, c);
}
fn dyn_nt(kern: &'static dispatch::KernelDesc, a: &Mat, b: &Mat, c: &mut Mat) {
    kernel::gemm_nt_into_with(kern, a, b, c);
}
fn dyn_tt(kern: &'static dispatch::KernelDesc, a: &Mat, b: &Mat, c: &mut Mat) {
    kernel::gemm_tt_into_with(kern, a, b, c);
}

/// The half-precision GEMM widens A on pack; that must be bitwise equal
/// (per variant) to widening A up front and running the f32 path, and
/// within quantization tolerance of the unquantized result.
#[test]
fn half_gemm_is_widen_on_pack_exact_and_within_quantization_tolerance() {
    let variants = dispatch::variants();
    let scalar = variants[0];
    let mut rng = Rng::new(911);
    let (m, k, n) = (33, 29, 21);
    let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
    let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
    let b_tn = Mat::random_uniform(m, n, -1.0, 1.0, &mut rng);
    let mut f32_ref = Mat::zeros(m, n);
    kernel::gemm_nn_into_with(scalar, &a, &b, &mut f32_ref, false);
    for (dtype, tol) in [(DType::F16, 2e-2f32), (DType::Bf16, 1.5e-1)] {
        let ah = HalfMat::from_f32(&a, dtype);
        let aw = ah.to_f32();
        // the bitwise reference: scalar f32 GEMM on the pre-widened A
        let mut want = Mat::zeros(m, n);
        kernel::gemm_nn_into_with(scalar, &aw, &b, &mut want, false);
        let mut want_tn = Mat::zeros(k, n);
        kernel::gemm_tn_into_with(scalar, &aw, &b_tn, &mut want_tn);
        for &kern in &variants {
            let mut got = Mat::zeros(m, n);
            kernel::gemm_nn_half_into_with(kern, &ah, &b, &mut got, false);
            assert_eq!(
                got.as_slice(),
                want.as_slice(),
                "{} half nn != widened f32 ({:?})",
                kern.name,
                dtype
            );
            let mut got_tn = Mat::zeros(k, n);
            kernel::gemm_tn_half_into_with(kern, &ah, &b_tn, &mut got_tn);
            assert_eq!(
                got_tn.as_slice(),
                want_tn.as_slice(),
                "{} half tn != widened f32 ({:?})",
                kern.name,
                dtype
            );
            // and close to the unquantized f32 answer
            assert_close(got.as_slice(), f32_ref.as_slice(), tol);
        }
    }
}

/// `gram_into` routes the mirrored lower triangle through the packed
/// path without allocating: once the thread-local pack scratch is warm,
/// repeated calls never resize it.
#[test]
fn gram_steady_state_performs_no_pack_allocations() {
    let mut rng = Rng::new(912);
    let a = Mat::random_uniform(200, 24, -1.0, 1.0, &mut rng);
    let mut g = Mat::zeros(24, 24);
    // warm the thread-local pack scratch (counter is per-thread, and the
    // test harness gives this test its own thread)
    kernel::gram_into(&a, &mut g);
    kernel::gram_into(&a, &mut g);
    let warm = kernel::pack_resize_count();
    for _ in 0..5 {
        kernel::gram_into(&a, &mut g);
    }
    assert_eq!(
        kernel::pack_resize_count(),
        warm,
        "steady-state gram_into must not grow the pack scratch"
    );
    // still exactly symmetric and correct
    for i in 0..24 {
        for j in 0..24 {
            assert_eq!(g[(i, j)], g[(j, i)]);
        }
    }
    let want = naive(24, 200, 24, |i, p| a[(p, i)], |p, j| a[(p, j)]);
    assert_close(g.as_slice(), want.as_slice(), 2e-3);
}

/// End-to-end precision acceptance: the same corpus ingested as f16
/// dense shards factorizes to the same relative error as the f32 store
/// (within 1e-3) — MU iterations stay f32, only the resident tile bytes
/// are halved.
#[test]
fn half_precision_corpus_factorizes_to_the_same_rel_error() {
    use drescal::engine::DatasetSpec;
    use drescal::store::{ingest_triples_file, IngestOptions};

    let dir = std::env::temp_dir()
        .join(format!("drescal_kernel_plane_half_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("kg.tsv");
    let mut rng = Rng::new(913);
    let (n, m) = (24usize, 2usize);
    let mut text = String::new();
    for i in 0..n {
        text.push_str(&format!("e{i}\tr{}\te{}\n", i % m, (i + 1) % n));
    }
    for _ in 0..400 {
        text.push_str(&format!(
            "e{}\tr{}\te{}\t{:.3}\n",
            rng.below(n),
            rng.below(m),
            rng.below(n),
            0.1 + rng.uniform_f32()
        ));
    }
    std::fs::write(&input, text).unwrap();

    let factorize = |dtype: DType| {
        let out = dir.join(format!("corpus_{}", dtype.as_str()));
        let report = ingest_triples_file(
            &input,
            &out,
            &IngestOptions {
                grid: 1,
                dense: true,
                dtype,
                source: input.display().to_string(),
            },
        )
        .unwrap();
        let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
        let data = engine
            .load_dataset(DatasetSpec::from_manifest_path(&report.manifest_path).unwrap())
            .unwrap();
        engine.factorize(data, &RescalOptions::new(4, 30), 42).unwrap().rel_error
    };
    let e32 = factorize(DType::F32);
    let e16 = factorize(DType::F16);
    assert!(
        (e32 - e16).abs() <= 1e-3,
        "f32 rel_error {e32} vs f16 rel_error {e16} drifted past 1e-3"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
