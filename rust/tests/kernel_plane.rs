//! Kernel-plane integration tests: packed-microkernel parity across
//! adversarial shapes and transpose variants, legacy-kernel agreement,
//! and the counter-asserted zero-allocation steady state of the
//! workspace-backed training hot loop.

use drescal::backend::native::NativeBackend;
use drescal::backend::{Backend, Workspace};
use drescal::comm::grid::run_on_grid;
use drescal::comm::Trace;
use drescal::data::synthetic::{self, SyntheticSpec};
use drescal::engine::{Engine, EngineConfig, Report};
use drescal::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use drescal::rescal::{LocalTile, ModelKind, RescalOptions};
use drescal::rng::Rng;
use drescal::tensor::dense::{gemm, gemm_legacy};
use drescal::tensor::{kernel, Mat};
use drescal::testing::{assert_close, naive_gemm as naive};

/// Shapes straddling the microkernel (MR/NR), blocking (MC/KC/NC), and
/// threading boundaries, plus degenerate vectors.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 300, 1),   // 1×n · n×1
    (300, 1, 5),   // m×1 outer-product-ish
    (5, 1, 300),
    (7, 9, 11),    // nothing divides MR/NR
    (8, 8, 8),     // exactly one microkernel tile
    (9, 257, 17),  // KC straddle with ragged edges
    (64, 64, 64),
    (65, 129, 127),
    (130, 40, 200),
];

#[test]
fn backend_variants_match_naive_reference_across_shapes() {
    let mut rng = Rng::new(900);
    let mut be = NativeBackend::new();
    for &(m, k, n) in SHAPES {
        let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
        let want = naive(m, k, n, |i, p| a[(i, p)], |p, j| b[(p, j)]);

        // NN via the backend into-API
        let mut c = Mat::zeros(m, n);
        be.matmul_into(&a, &b, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // TN: Aᵀ·B with A stored k-major
        let at = a.transpose();
        let mut c = Mat::zeros(m, n);
        be.t_matmul_into(&at, &b, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // NT: A·Bᵀ with B stored n×k
        let bt = b.transpose();
        let mut c = Mat::zeros(m, n);
        be.matmul_t_into(&a, &bt, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // TT via the kernel entry point (no Backend method needs it yet)
        let mut c = Mat::zeros(m, n);
        kernel::gemm_tt_into(&at, &bt, &mut c);
        assert_close(c.as_slice(), want.as_slice(), 2e-3);

        // gram: AᵀA, exactly symmetric
        let mut g = Mat::zeros(k, k);
        be.gram_into(&a, &mut g);
        let want_g = naive(k, m, k, |i, p| a[(p, i)], |p, j| a[(p, j)]);
        assert_close(g.as_slice(), want_g.as_slice(), 2e-3);
        for i in 0..k {
            for j in 0..k {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }
}

#[test]
fn packed_and_legacy_kernels_agree_serial_and_threaded() {
    let mut rng = Rng::new(901);
    // small stays serial; the large ones cross the 2^20 FMA threshold on
    // multi-core hosts and take the threaded macro-panel path
    for &(m, k, n) in &[(6, 10, 4), (150, 120, 110), (300, 130, 90)] {
        let a = Mat::random_uniform(m, k, -1.0, 1.0, &mut rng);
        let b = Mat::random_uniform(k, n, -1.0, 1.0, &mut rng);
        let mut packed = Mat::zeros(m, n);
        gemm(&a, &b, &mut packed, false);
        let mut legacy = Mat::zeros(m, n);
        gemm_legacy(&a, &b, &mut legacy, false);
        assert_close(packed.as_slice(), legacy.as_slice(), 2e-3);
        let want = naive(m, k, n, |i, p| a[(i, p)], |p, j| b[(p, j)]);
        assert_close(packed.as_slice(), want.as_slice(), 2e-3);
    }
}

#[test]
fn high_level_mat_ops_ride_the_packed_kernel() {
    let mut rng = Rng::new(902);
    let a = Mat::random_uniform(33, 21, -1.0, 1.0, &mut rng);
    let b = Mat::random_uniform(21, 19, -1.0, 1.0, &mut rng);
    let want = naive(33, 21, 19, |i, p| a[(i, p)], |p, j| b[(p, j)]);
    assert_close(a.matmul(&b).as_slice(), want.as_slice(), 1e-3);
    assert_close(
        a.transpose().t_matmul(&b).as_slice(),
        want.as_slice(),
        1e-3,
    );
    assert_close(a.matmul_t(&b.transpose()).as_slice(), want.as_slice(), 1e-3);
    assert_close(a.gram().as_slice(), a.t_matmul(&a).as_slice(), 1e-3);
}

/// The zero-allocation guarantee inside one job: every iteration
/// temporary is checked out before the MU loop, so the workspace alloc
/// count is independent of how many iterations run.
#[test]
fn factorize_allocs_are_independent_of_iteration_count() {
    let x = synthetic::planted_tensor(16, 2, 3, 0.0, 903).x;
    let run = |iters: usize| {
        let results = run_on_grid(1, |ctx| {
            let tile = LocalTile::Dense(x.clone());
            let cfg = DistRescalConfig {
                opts: RescalOptions::new(3, iters),
                init: DistInit::Random { seed: 4 },
                n: 16,
                model: ModelKind::Rescal,
            };
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::disabled();
            rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescal_rank")
                .workspace
        });
        results[0]
    };
    let one = run(1);
    let many = run(12);
    assert!(one.mat_allocs > 0, "cold workspace must allocate the iteration buffers");
    assert_eq!(
        one.mat_allocs, many.mat_allocs,
        "12 iterations must allocate exactly what 1 iteration does — \
         all subsequent iterations are buffer reuse"
    );
}

/// The zero-allocation guarantee across jobs: the engine's rank pool
/// keeps each rank's workspace alive, so a repeated factorize job
/// performs zero workspace allocations — every checkout is arena reuse.
#[test]
fn warm_pool_factorize_performs_zero_workspace_allocations() {
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let data = engine.load_dataset(SyntheticSpec::dense(24, 2, 3, 7)).unwrap();
    let opts = RescalOptions::new(3, 6);
    let cold = engine.factorize(data, &opts, 42).unwrap();
    assert!(cold.workspace.mat_allocs > 0, "cold ranks must populate their arenas");
    let warm = engine.factorize(data, &opts, 42).unwrap();
    assert_eq!(
        warm.workspace.mat_allocs, 0,
        "a warm rank pool must serve every iteration temporary from reuse"
    );
    assert_eq!(
        warm.workspace.mat_reuses, cold.workspace.mat_allocs + cold.workspace.mat_reuses,
        "warm job checks out exactly the buffers the cold job allocated"
    );
    // results are identical — the arena changes where buffers live, not
    // what the algorithm computes
    assert_close(warm.a.as_slice(), cold.a.as_slice(), 1e-6);
    assert_eq!(warm.rel_error, cold.rel_error);
}

#[test]
fn report_json_carries_workspace_counters() {
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let data = engine.load_dataset(SyntheticSpec::dense(12, 2, 2, 5)).unwrap();
    let report = engine.factorize(data, &RescalOptions::new(2, 3), 1).unwrap();
    let ws = report.workspace;
    let json = Report::Factorize(report).to_json();
    let back = Report::from_json(&json).unwrap();
    match back {
        Report::Factorize(r) => assert_eq!(r.workspace, ws),
        _ => panic!("kind changed in roundtrip"),
    }
    // archived pre-kernel-plane reports (no workspace field) still parse
    let legacy = drescal::json::Json::parse(
        r#"{"kind":"simulate","scenario":"s","runs":[]}"#,
    )
    .unwrap();
    assert!(Report::from_json(&legacy).is_ok());
}

/// The sparse residual accumulator walks CSR structure directly; it must
/// agree with the dense residual on identical data (and never densify).
#[test]
fn sparse_residual_matches_dense_on_shared_data() {
    let mut rng = Rng::new(905);
    let s = vec![drescal::tensor::Csr::random(40, 40, 0.15, &mut rng)];
    let dense = drescal::tensor::Tensor3::from_slices(vec![s[0].to_dense()]);
    let a_row = Mat::random_uniform(40, 3, 0.0, 1.0, &mut rng);
    let a_col = Mat::random_uniform(40, 3, 0.0, 1.0, &mut rng);
    let r = Mat::random_uniform(3, 3, 0.0, 1.0, &mut rng);
    let ar = a_row.matmul(&r);
    let d = LocalTile::Dense(dense).residual_sq(0, &ar, &a_col);
    let sp = LocalTile::Sparse(s).residual_sq(0, &ar, &a_col);
    assert!((d - sp).abs() < 1e-3 * d.max(1.0), "dense {d} vs sparse {sp}");
}
