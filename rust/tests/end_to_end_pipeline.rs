//! End-to-end integration over all three layers: the distributed RESCAL
//! hot path executing the AOT JAX+Pallas artifacts through PJRT, inside
//! the virtual-MPI grid, must converge and agree with the native backend.
//!
//! Requires the `pjrt` feature (the default stub runtime never serves
//! artifacts, so the `hits > 0` assertion below would fail) and
//! `make artifacts` (skips when absent).
#![cfg(feature = "pjrt")]

use drescal::backend::{native::NativeBackend, xla::XlaBackend, Backend, Workspace};
use drescal::comm::grid::run_on_grid;
use drescal::comm::Trace;
use drescal::data::synthetic;
use drescal::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use drescal::rescal::{LocalTile, ModelKind, RescalOptions};

fn artifact_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

/// n=64 on a 2×2 grid gives 32×32 tiles — exactly the tile size baked into
/// the default artifact set, so the XLA backend serves the hot path.
#[test]
fn distributed_rescal_over_pjrt_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let n = 64;
    let k = 4;
    let planted = synthetic::block_tensor(n, 3, k, 0.01, 1000);
    let x = planted.x.clone();
    let opts = RescalOptions::new(k, 150);

    let run = |use_xla: bool| {
        run_on_grid(4, |ctx| {
            let (r0, r1) = ctx.grid.chunk(n, ctx.row);
            let (c0, c1) = ctx.grid.chunk(n, ctx.col);
            let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
            let cfg = DistRescalConfig {
                opts: opts.clone(),
                init: DistInit::Random { seed: 12 },
                n,
                model: ModelKind::Rescal,
            };
            let mut ws = Workspace::new();
            let mut trace = Trace::new();
            if use_xla {
                let mut backend = XlaBackend::new(&dir).expect("xla backend");
                let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                    .expect("in-process rescal_rank");
                (out.rel_error, backend.hits, backend.fallbacks)
            } else {
                let mut backend = NativeBackend::new();
                let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                    .expect("in-process rescal_rank");
                (out.rel_error, 0, 0)
            }
        })
    };

    let xla_results = run(true);
    let native_results = run(false);
    for ((xe, hits, fallbacks), (ne, _, _)) in xla_results.iter().zip(&native_results) {
        // the artifact path must carry the hot loop
        assert!(*hits > 0, "no PJRT executions recorded");
        eprintln!("rel_error xla={xe:.4} native={ne:.4} hits={hits} fallbacks={fallbacks}");
        // both backends implement the same math
        assert!((xe - ne).abs() < 5e-3, "xla {xe} vs native {ne}");
        // and the factorization is good
        assert!(*xe < 0.15, "rel_error={xe}");
    }
}
