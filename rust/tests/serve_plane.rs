//! Serving-plane integration: scoring parity, determinism, artifact
//! round-trips, and the LRU/batching counter guarantees.
//!
//! The acceptance bar: batched/threaded top-k must *exactly* match a
//! brute-force `score(s,r,o)` loop (for both `Factorize`- and
//! `ModelSelect`-derived models), a model must survive a JSON
//! save→load→re-query round-trip, and a repeated query must be served
//! from the LRU cache with zero additional scored candidates.

use drescal::backend::Workspace;
use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{Engine, EngineConfig, Report};
use drescal::model_selection::RescalkConfig;
use drescal::rescal::RescalOptions;
use drescal::rng::Rng;
use drescal::serve::score::{brute_force_top_k, complete_batch, score_one, top_k_chunked};
use drescal::serve::{
    Answer, Direction, FactorModel, Provenance, Query, QueryEngine,
};
use drescal::tensor::{Mat, Tensor3};

/// A trained model from a real factorize job on the engine.
fn factorize_model() -> FactorModel {
    let planted = synthetic::block_tensor(24, 2, 3, 0.01, 501);
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let data = engine.load_dataset(JobData::dense(planted.x)).unwrap();
    let report = engine.factorize(data, &RescalOptions::new(3, 150), 7).unwrap();
    engine.export_model(&Report::Factorize(report)).unwrap()
}

/// Per-anchor parity: the batched GEMM path must rank candidates
/// exactly like the brute-force pointwise loop, ties included.
fn assert_parity(model: &FactorModel, top: usize) {
    let anchors: Vec<usize> = (0..model.n()).collect();
    let mut ws = Workspace::new();
    for dir in [Direction::Objects, Direction::Subjects] {
        for rel in 0..model.m() {
            let batched = complete_batch(model, dir, rel, &anchors, top, &mut ws).unwrap();
            for (anchor, got) in anchors.iter().zip(&batched) {
                let want = brute_force_top_k(model, dir, rel, *anchor, top).unwrap();
                let got_idx: Vec<usize> = got.iter().map(|h| h.entity).collect();
                let want_idx: Vec<usize> = want.iter().map(|h| h.entity).collect();
                assert_eq!(
                    got_idx, want_idx,
                    "dir={dir:?} rel={rel} anchor={anchor}: batched != brute force"
                );
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.score - w.score).abs() < 1e-5,
                        "score drift at dir={dir:?} rel={rel} anchor={anchor}"
                    );
                }
            }
        }
    }
}

#[test]
fn factorize_model_batched_topk_matches_brute_force() {
    let model = factorize_model();
    assert_eq!(model.provenance().job, "factorize");
    assert_eq!(model.provenance().p, 4, "engine stamps its grid into provenance");
    assert_parity(&model, 5);
}

#[test]
fn model_select_model_batched_topk_matches_brute_force() {
    // same planted tensor + sweep parameters as the coordinator tests,
    // which are known to recover k = 2
    let planted = synthetic::block_tensor(20, 2, 2, 0.01, 1201);
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let data = engine.load_dataset(JobData::dense(planted.x)).unwrap();
    let cfg = RescalkConfig {
        k_min: 1,
        k_max: 4,
        perturbations: 5,
        rescal_iters: 500,
        regress_iters: 25,
        seed: 9,
        ..Default::default()
    };
    let report = engine.model_select(data, &cfg).unwrap();
    let model = engine.export_model(&Report::ModelSelect(report)).unwrap();
    assert_eq!(model.provenance().job, "model_select");
    assert_eq!(model.k(), 2, "sweep recovers the planted k");
    assert!(model.provenance().rel_error >= 0.0, "k_opt rel_error recorded");
    assert_parity(&model, 4);
}

#[test]
fn topk_is_deterministic_across_chunk_counts_under_ties() {
    // many exact ties: every entity in a community block shares factor
    // rows, so scores collide and only the index tie-break orders them
    let a = Mat::from_fn(32, 2, |i, j| if (i / 8) % 2 == j { 1.0 } else { 0.25 });
    let r = Tensor3::from_slices(vec![Mat::eye(2)]);
    let model = FactorModel::new(a, r, Provenance::external()).unwrap();
    let reference =
        complete_batch(&model, Direction::Objects, 0, &[0], 12, &mut Workspace::new()).unwrap();
    // tied candidates must come out in ascending entity order
    let top = &reference[0];
    for pair in top.windows(2) {
        assert!(
            pair[0].score > pair[1].score
                || (pair[0].score == pair[1].score && pair[0].entity < pair[1].entity),
            "tie broken away from the lower index: {pair:?}"
        );
    }
    // raw selection kernel: identical output for every chunking of the
    // same score vector (what a different thread count would produce)
    let mut rng = Rng::new(77);
    let mut scores = vec![0.0f32; 4096];
    rng.fill_uniform(&mut scores, 0.0, 1.0);
    for i in (0..4096).step_by(3) {
        scores[i] = 0.75; // plateau of ties
    }
    let want = top_k_chunked(&scores, 64, 1);
    for chunks in [2, 4, 7, 16, 64, 4096] {
        assert_eq!(top_k_chunked(&scores, 64, chunks), want, "chunks={chunks}");
    }
}

#[test]
fn model_json_roundtrip_requeries_identically() {
    let model = factorize_model();
    let dir = std::env::temp_dir().join(format!("drescal_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    model.save(&path).unwrap();
    let reloaded = FactorModel::load(&path).unwrap();
    assert_eq!(reloaded.a(), model.a(), "A survives the JSON round-trip exactly");
    assert_eq!(reloaded.r(), model.r(), "R survives the JSON round-trip exactly");
    assert_eq!(reloaded.provenance(), model.provenance());

    // re-query: answers from the reloaded model are identical
    let queries: Vec<Query> = (0..model.n())
        .map(|s| Query::TopObjects { s, r: 1, top: 4 })
        .chain((0..model.n()).map(|o| Query::TopSubjects { o, r: 0, top: 3 }))
        .chain([Query::Score { s: 0, r: 0, o: 5 }])
        .collect();
    let mut qe1 = QueryEngine::new(model);
    let mut qe2 = QueryEngine::new(reloaded);
    let a1 = qe1.submit_batch(&queries).unwrap();
    let a2 = qe2.submit_batch(&queries).unwrap();
    assert_eq!(a1, a2, "save -> load -> re-query must be the identity");
    std::fs::remove_dir_all(&dir).ok();
}

/// The headline cache guarantee (acceptance criterion): a repeated
/// query is answered from the LRU with **zero additional scored
/// candidates**, while a threaded/batched top-k stays exactly equal to
/// brute force.
#[test]
fn repeated_query_served_from_cache_with_zero_scoring() {
    let model = factorize_model();
    let n = model.n();
    let brute = brute_force_top_k(&model, Direction::Objects, 0, 3, 5).unwrap();
    let mut qe = QueryEngine::new(model);
    let q = Query::TopObjects { s: 3, r: 0, top: 5 };

    let first = qe.query(q).unwrap();
    assert_eq!(first, Answer::TopK(brute), "served top-k == brute-force top-k");
    let cold = qe.stats();
    assert_eq!(cold.queries, 1);
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.batches, 1);
    assert_eq!(cold.scored_candidates, n, "one anchor scored against all n");

    let second = qe.query(q).unwrap();
    let warm = qe.stats();
    assert_eq!(second, first, "cache returns the identical answer");
    assert_eq!(warm.cache_hits, 1);
    assert_eq!(warm.batches, cold.batches, "no new GEMM batch");
    assert_eq!(
        warm.scored_candidates, cold.scored_candidates,
        "zero additional scored candidates on a cache hit"
    );
}

#[test]
fn micro_batch_coalesces_same_relation_queries_into_one_gemm() {
    let model = factorize_model();
    let n = model.n();
    let mut qe = QueryEngine::with_cache_capacity(model, 0);
    let batch: Vec<Query> =
        (0..6).map(|s| Query::TopObjects { s, r: 0, top: 3 }).collect();
    qe.submit_batch(&batch).unwrap();
    let stats = qe.stats();
    assert_eq!(stats.batches, 1, "six same-relation queries share one GEMM");
    assert_eq!(stats.scored_candidates, 6 * n);
}

#[test]
fn export_is_typed_about_factorless_reports() {
    use drescal::engine::{SimScenario, SimSpec};
    use drescal::simulate::Machine;
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let sim = engine
        .simulate(SimSpec { machine: Machine::cpu_cluster(), scenario: SimScenario::Dense11Tb })
        .unwrap();
    let e = engine.export_model(&Report::Simulate(sim)).unwrap_err();
    assert!(e.to_string().contains("simulate"), "{e}");
}

#[test]
fn serve_bench_helpers_count_what_they_score() {
    let mut rng = Rng::new(5);
    let a = Mat::random_uniform(20, 3, 0.0, 1.0, &mut rng);
    let r = Tensor3::random_uniform(3, 3, 2, 0.0, 1.0, &mut rng);
    let model = FactorModel::new(a, r, Provenance::external()).unwrap();

    // batch 10 divides the 20 subjects per relation evenly, so every
    // micro-batch holds one relation and maps to exactly one GEMM
    let point = drescal::bench_util::measure_serve_topk(&model, 10, 40, 5).unwrap();
    assert_eq!(point.stats.queries, 40);
    assert_eq!(point.stats.cache_hits, 0, "throughput pass runs uncached");
    assert_eq!(point.stats.batches, 40 / 10, "one GEMM per full micro-batch");
    assert_eq!(point.stats.scored_candidates, 40 * 20);

    let (cold, warm) =
        drescal::bench_util::measure_serve_cached_replay(&model, 10, 40, 5).unwrap();
    assert_eq!(cold.stats.queries, 40);
    assert_eq!(warm.stats.queries, 40);
    assert_eq!(warm.stats.cache_hits, 40, "replay is all cache hits");
    assert_eq!(warm.stats.scored_candidates, 0, "replay scores nothing");
    assert_eq!(warm.stats.batches, 0);
    assert!(cold.stats.scored_candidates > 0);
}

#[test]
fn out_of_range_queries_are_typed_errors() {
    let model = factorize_model();
    let n = model.n();
    let m = model.m();
    assert!(score_one(&model, n, 0, 0).is_err());
    assert!(score_one(&model, 0, m, 0).is_err());
    let mut qe = QueryEngine::new(model);
    assert!(qe.query(Query::TopObjects { s: n, r: 0, top: 3 }).is_err());
    assert!(qe.query(Query::TopSubjects { o: 0, r: m, top: 3 }).is_err());
    assert_eq!(qe.stats().queries, 0, "failed queries answer nothing");
}
