//! Transport plane integration: the TCP backend must be bit-identical
//! to the in-process backend at every level — raw collectives, grid
//! contexts, and whole factorization runs across real OS processes —
//! and a killed worker must be replaceable without changing the result.

use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use drescal::comm::transport::tcp::{loopback_meshes, rank_ctx_from_mesh, TcpConfig, TcpGroup};
use drescal::comm::{Group, RankCtx};

/// Deterministic per-rank payload with values that exercise f32
/// rounding when summed in different orders.
fn payload(tag: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| ((tag * 31 + i * 7) % 97) as f32 * 0.137 - 3.0).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// World-group handles over a fresh TCP loopback mesh.
fn tcp_world_groups(size: usize) -> Vec<Group> {
    loopback_meshes(size, TcpConfig::default())
        .expect("loopback mesh")
        .into_iter()
        .map(|m| {
            TcpGroup::new(Arc::new(Mutex::new(m)), (0..size).collect(), 0)
                .map(Group::from_transport)
                .expect("tcp group")
        })
        .collect()
}

/// Run `f` on every member handle, one OS thread each, results in
/// member order.
fn on_each<T: Send>(groups: Vec<Group>, f: impl Fn(Group) -> T + Sync) -> Vec<T> {
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = groups.into_iter().map(|g| s.spawn(move || f(g))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Every collective, across both world sizes and payload shapes the
/// engine actually produces (including empty and non-power-of-two),
/// must agree with the in-process backend to the last bit.
#[test]
fn tcp_collectives_match_inprocess_bitwise() {
    for size in [1usize, 4] {
        for len in [0usize, 1, 7, 64] {
            let run = |groups: Vec<Group>| {
                on_each(groups, |g| {
                    let r = g.rank;
                    let root = g.size() - 1;
                    let mut sum = payload(r, len);
                    g.all_reduce_sum(&mut sum).unwrap();
                    let mut max = payload(r + 5, len);
                    g.all_reduce_max(&mut max).unwrap();
                    let mut bc =
                        if r == root { payload(9, len) } else { vec![0.0; len] };
                    g.broadcast(root, &mut bc).unwrap();
                    let gathered = g.all_gather(&payload(r, len)).unwrap();
                    (bits(&sum), bits(&max), bits(&bc), bits(&gathered))
                })
            };
            let inproc = run(Group::create(size));
            let tcp = run(tcp_world_groups(size));
            assert_eq!(inproc, tcp, "divergence at size={size} len={len}");
        }
    }
}

/// Ragged sub-communicators: a 6-rank world arranged 2×3, with row
/// groups of 3 and column groups of 2 sharing one mesh per rank. The
/// in-process reference builds the same arrangement from independent
/// groups.
#[test]
fn ragged_2x3_subgroups_match_inprocess() {
    type Pair = (Group, Group); // (row handle, col handle) per world rank
    let work = |pairs: Vec<Pair>| {
        std::thread::scope(|s| {
            let handles: Vec<_> = pairs
                .into_iter()
                .enumerate()
                .map(|(r, (row, col))| {
                    s.spawn(move || {
                        let mut v = payload(r, 5);
                        row.all_reduce_sum(&mut v).unwrap();
                        let gathered = col.all_gather(&v).unwrap();
                        // column member 0 is world rank r % 3 under both
                        // backends, so the broadcast source data agrees
                        let mut b =
                            if col.rank == 0 { payload(r, 3) } else { vec![0.0; 3] };
                        col.broadcast(0, &mut b).unwrap();
                        (bits(&v), bits(&gathered), bits(&b))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        })
    };

    // in-process: world rank r sits in row r/3 (member r%3) and column
    // r%3 (member r/3); pop in reverse rank order like RankCtx does
    let mut rows: Vec<Vec<Group>> = (0..2).map(|_| Group::create(3)).collect();
    let mut cols: Vec<Vec<Group>> = (0..3).map(|_| Group::create(2)).collect();
    let mut inproc_pairs: Vec<Pair> = Vec::new();
    for r in (0..6usize).rev() {
        inproc_pairs.push((rows[r / 3].pop().unwrap(), cols[r % 3].pop().unwrap()));
    }
    inproc_pairs.reverse();

    // tcp: both subgroup handles of a rank share its mesh, with group
    // ids agreed across the world (rows 1..=2, columns 3..=5)
    let tcp_pairs: Vec<Pair> = loopback_meshes(6, TcpConfig::default())
        .expect("loopback mesh")
        .into_iter()
        .enumerate()
        .map(|(r, m)| {
            let m = Arc::new(Mutex::new(m));
            let base = (r / 3) * 3;
            let row = TcpGroup::new(m.clone(), (base..base + 3).collect(), 1 + (r / 3) as u32)
                .map(Group::from_transport)
                .expect("row group");
            let col = TcpGroup::new(m, vec![r % 3, r % 3 + 3], 3 + (r % 3) as u32)
                .map(Group::from_transport)
                .expect("col group");
            (row, col)
        })
        .collect();

    assert_eq!(work(inproc_pairs), work(tcp_pairs), "ragged subgroup divergence");
}

/// Full grid contexts (world + row + column communicators) built from
/// TCP meshes must agree with `RankCtx::create_all`.
#[test]
fn grid_contexts_match_inprocess_bitwise() {
    for p in [1usize, 4] {
        let run = |ctxs: Vec<RankCtx>| {
            std::thread::scope(|s| {
                let handles: Vec<_> = ctxs
                    .into_iter()
                    .map(|ctx| {
                        s.spawn(move || {
                            let mut v = payload(ctx.rank, 6);
                            ctx.row_comm.all_reduce_sum(&mut v).unwrap();
                            let mut w = payload(ctx.rank + 11, 6);
                            ctx.col_comm.all_reduce_sum(&mut w).unwrap();
                            let g =
                                ctx.world.all_gather(&[ctx.rank as f32 * 0.3]).unwrap();
                            (bits(&v), bits(&w), bits(&g))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
            })
        };
        let inproc = run(RankCtx::create_all(p));
        let grid = drescal::comm::Grid::new(p);
        let tcp_ctxs: Vec<RankCtx> = loopback_meshes(p, TcpConfig::default())
            .expect("loopback mesh")
            .into_iter()
            .map(|m| rank_ctx_from_mesh(m, grid).expect("tcp rank ctx"))
            .collect();
        assert_eq!(inproc, run(tcp_ctxs), "grid divergence at p={p}");
    }
}

/// The TCP backend reports real framed wire traffic, not a model.
#[test]
fn tcp_wire_stats_count_real_traffic() {
    let stats = on_each(tcp_world_groups(4), |g| {
        let mut v = vec![1.0f32; 16];
        g.all_reduce_sum(&mut v).unwrap();
        (g.backend(), g.wire_stats())
    });
    for (backend, st) in stats {
        assert_eq!(backend, "tcp");
        assert!(st.ops > 0, "tcp op count not recorded");
        // a 4-rank ring all-reduce of 64 payload bytes moves at least
        // one frame of payload plus headers in each direction
        assert!(st.bytes > 64, "tcp byte count implausibly low: {}", st.bytes);
    }
}

// ---------------------------------------------------------------------
// multi-process: real `drescal train` / `drescal worker` children
// ---------------------------------------------------------------------

fn drescal() -> Command {
    Command::new(env!("CARGO_BIN_EXE_drescal"))
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("drescal_transport_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Poll until the leader writes its bound address to the port file.
fn wait_port_file(path: &Path) -> String {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let t = s.trim();
            if !t.is_empty() {
                return t.to_string();
            }
        }
        assert!(
            Instant::now() < deadline,
            "leader never wrote its port file {}",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn spawn_worker(addr: &str) -> Child {
    drescal()
        .args(["worker", "--connect", addr])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn drescal worker")
}

/// Wait for a child with a deadline; kill and fail if it wedges.
fn reap(mut child: Child, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(25));
            }
            _ => {
                let _ = child.kill();
                let _ = child.wait();
                panic!("{what} did not exit after the leader finished");
            }
        }
    }
}

fn digest_of(text: &str) -> String {
    text.lines()
        .find_map(|l| l.strip_prefix("factor digest: "))
        .unwrap_or_else(|| panic!("no factor digest line in:\n{text}"))
        .trim()
        .to_string()
}

fn combined(out: &std::process::Output) -> String {
    format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    )
}

/// Read leader stdout until the line proving the dataset is loaded and
/// the factorize job is about to be dispatched.
fn read_until_job_start(stdout: &mut BufReader<std::process::ChildStdout>) -> String {
    let mut seen = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        if stdout.read_line(&mut line).unwrap() == 0 {
            panic!("leader exited before dispatching the job:\n{seen}");
        }
        seen.push_str(&line);
        if line.contains("cluster RESCAL") {
            return seen;
        }
    }
}

/// A 2×2 TCP cluster of real OS processes must produce bit-identical
/// factors to the single-process in-process engine: same data spec,
/// same seed, same digest.
#[test]
fn tcp_cluster_run_is_bit_identical_to_inprocess() {
    let dir = tmpdir("parity");
    let port_file = dir.join("leader.addr");
    let data = [
        "--data", "synthetic", "--n", "40", "--m", "2", "--k-true", "3", "--density",
        "0.2", "--k", "3", "--iters", "30", "--seed", "7",
    ];
    let leader = drescal()
        .arg("train")
        .args(data)
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn leader");
    let addr = wait_port_file(&port_file);
    let workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();
    let out = leader.wait_with_output().expect("leader run");
    let text = combined(&out);
    for w in workers {
        reap(w, "worker");
    }
    assert!(out.status.success(), "leader failed:\n{text}");
    assert!(text.contains("transport tcp"), "report not stamped tcp:\n{text}");
    let cluster = digest_of(&text);

    let solo = drescal().arg("run").args(data).args(["--p", "4"]).output().unwrap();
    let solo_text = combined(&solo);
    assert!(solo.status.success(), "{solo_text}");
    assert_eq!(
        digest_of(&solo_text),
        cluster,
        "TCP cluster factors diverge from in-process factors"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos: ingest a corpus to tile shards, start a 2×2 cluster over it,
/// kill one worker mid-factorization, and offer a fresh process. The
/// leader must admit the replacement, replay the dead rank's dataset
/// load from the shards on disk, rerun the job, and land on exactly
/// the factors the in-process engine computes.
#[test]
fn killed_worker_is_replaced_and_factors_match() {
    let dir = tmpdir("chaos");
    let triples = dir.join("kg.tsv");
    let mut text = String::new();
    for i in 0..60 {
        for j in 0..60 {
            if (i + 2 * j) % 5 == 0 {
                text.push_str(&format!("e{i}\trel0\te{j}\n"));
            }
            if (i * j) % 11 == 3 {
                text.push_str(&format!("e{i}\trel1\te{j}\n"));
            }
        }
    }
    std::fs::write(&triples, text).unwrap();
    let corpus = dir.join("corpus");
    let ingest = drescal()
        .args(["ingest", "--input", triples.to_str().unwrap()])
        .args(["--out", corpus.to_str().unwrap(), "--grid", "2"])
        .output()
        .unwrap();
    assert!(ingest.status.success(), "{}", combined(&ingest));
    let file_arg = format!("file:{}", corpus.join("manifest.json").display());
    let common = ["--k", "3", "--iters", "500", "--seed", "3"];

    let solo = drescal()
        .arg("run")
        .args(["--data", &file_arg])
        .args(common)
        .args(["--p", "4"])
        .output()
        .unwrap();
    let solo_text = combined(&solo);
    assert!(solo.status.success(), "{solo_text}");
    let want = digest_of(&solo_text);

    let port_file = dir.join("leader.addr");
    let mut leader = drescal()
        .arg("train")
        .args(["--data", &file_arg])
        .args(common)
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .args(["--comm-timeout-ms", "2000", "--max-replacements", "1"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let addr = wait_port_file(&port_file);
    let mut workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();

    let mut stdout = BufReader::new(leader.stdout.take().unwrap());
    let mut seen = read_until_job_start(&mut stdout);

    // kill a worker while the factorization is in flight, then offer a
    // fresh process as its replacement
    let mut victim = workers.remove(1);
    victim.kill().unwrap();
    let _ = victim.wait();
    workers.push(spawn_worker(&addr));

    stdout.read_to_string(&mut seen).unwrap();
    let status = leader.wait().unwrap();
    let mut err = String::new();
    leader.stderr.take().unwrap().read_to_string(&mut err).unwrap();
    for w in workers {
        reap(w, "worker");
    }
    assert!(status.success(), "leader failed\nstdout:\n{seen}\nstderr:\n{err}");
    assert!(
        err.contains("recovered at epoch"),
        "worker kill was not detected/recovered\nstderr:\n{err}"
    );
    assert_eq!(
        digest_of(&seen),
        want,
        "recovered cluster diverged from in-process factors\nstderr:\n{err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// With the replacement budget at zero, a worker crash is a typed job
/// failure: the leader exits non-zero naming the exhausted budget
/// instead of hanging or corrupting state.
#[test]
fn replacement_budget_zero_fails_with_typed_error() {
    let dir = tmpdir("budget");
    let port_file = dir.join("leader.addr");
    let mut leader = drescal()
        .arg("train")
        .args(["--data", "synthetic", "--n", "48", "--m", "2", "--k-true", "3"])
        .args(["--density", "0.3", "--k", "3", "--iters", "2000", "--seed", "11"])
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .args(["--comm-timeout-ms", "1500", "--max-replacements", "0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let addr = wait_port_file(&port_file);
    let mut workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();

    let mut stdout = BufReader::new(leader.stdout.take().unwrap());
    let mut seen = read_until_job_start(&mut stdout);
    let mut victim = workers.remove(0);
    victim.kill().unwrap();
    let _ = victim.wait();

    stdout.read_to_string(&mut seen).unwrap();
    let status = leader.wait().unwrap();
    let mut err = String::new();
    leader.stderr.take().unwrap().read_to_string(&mut err).unwrap();
    for w in workers {
        reap(w, "worker");
    }
    assert!(
        !status.success(),
        "leader must fail with no replacement budget\nstdout:\n{seen}\nstderr:\n{err}"
    );
    assert!(
        err.contains("worker-replacement budget"),
        "missing typed budget error\nstderr:\n{err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
