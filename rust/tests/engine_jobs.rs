//! Engine job API integration: one engine, many jobs, no respawn.
//!
//! Covers the pool-reuse guarantee (the acceptance criterion of the
//! engine redesign), unified-report JSON round-trips, and the engine's
//! typed error paths.

use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{
    Engine, EngineConfig, JobSpec, Report, SimScenario, SimSpec,
};
use drescal::json::Json;
use drescal::model_selection::RescalkConfig;
use drescal::rescal::distributed::DistInit;
use drescal::rescal::{ModelKind, RescalOptions};
use drescal::simulate::Machine;

/// The headline guarantee: consecutive jobs of *different kinds* run on
/// the same rank threads with the same backends — nothing respawns or
/// rebuilds between submissions.
#[test]
fn engine_runs_consecutive_jobs_on_one_pool() {
    let mut engine = Engine::new(EngineConfig::new(4).with_trace(true)).unwrap();
    let ids_at_start = engine.ping().unwrap();
    assert_eq!(ids_at_start.len(), 4);
    assert_eq!(engine.stats().backend_builds, 4, "one backend per rank at spawn");

    // same planted tensor + sweep parameters as the in-module
    // model-selection tests, which are known to recover k = 3
    let planted = synthetic::block_tensor(24, 3, 3, 0.01, 700);
    let data = JobData::dense(planted.x.clone());

    // job 1: factorization
    let report = engine.factorize(&data, &RescalOptions::new(3, 150), 7).unwrap();
    assert_eq!(report.a.shape(), (24, 3));
    assert!(report.rel_error < 0.15, "err={}", report.rel_error);
    assert_eq!(report.traces.len(), 4);
    // gathered A actually reconstructs the tensor
    let direct = planted.x.rel_error(&report.a, &report.r);
    assert!((direct - report.rel_error).abs() < 1e-3);

    // job 2: model selection on the same pool
    let cfg = RescalkConfig {
        k_min: 2,
        k_max: 5,
        perturbations: 6,
        rescal_iters: 150,
        regress_iters: 30,
        seed: 1,
        ..Default::default()
    };
    let sweep = engine.model_select(&data, &cfg).unwrap();
    assert_eq!(sweep.k_opt, 3, "scores {:?}", sweep.scores);
    assert_eq!(sweep.a.shape(), (24, 3));

    // job 3: another factorization, via the raw JobSpec interface
    let report2 = engine
        .submit(JobSpec::Factorize {
            data: (&data).into(),
            opts: RescalOptions::new(3, 50),
            init: DistInit::Random { seed: 8 },
            model: ModelKind::Rescal,
        })
        .unwrap();
    assert!(matches!(report2, Report::Factorize(_)));

    // pool reuse: same worker threads, no extra backend builds
    let ids_at_end = engine.ping().unwrap();
    assert_eq!(ids_at_start, ids_at_end, "rank threads were respawned");
    let stats = engine.stats();
    assert_eq!(stats.ranks, 4);
    assert_eq!(
        stats.backend_builds, 4,
        "backends were rebuilt between jobs ({} builds for 3 jobs)",
        stats.backend_builds
    );
    // all three jobs shared one JobData, so the inline compat path
    // auto-registered it exactly once: p tile extractions total
    assert_eq!(stats.tile_builds, 4, "tiles were re-extracted between jobs");
    assert_eq!(stats.datasets_resident, 1);
    assert_eq!(stats.jobs_completed, 3);
}

#[test]
fn factorize_report_roundtrips_through_json() {
    let mut engine = Engine::new(EngineConfig::new(4).with_trace(true)).unwrap();
    let planted = synthetic::block_tensor(16, 2, 2, 0.01, 99);
    let data = JobData::dense(planted.x);
    let report = engine.factorize(&data, &RescalOptions::new(2, 60), 1).unwrap();
    let (rel_error, iters_run, a_shape) =
        (report.rel_error, report.iters_run, report.a.shape());

    let json = Report::Factorize(report).to_json();
    // Report -> Json -> text -> parse is lossless at the Json level
    let reparsed = Json::parse(&json.to_string()).unwrap();
    assert_eq!(reparsed, json);

    // and the parsed form rebuilds the same report
    match Report::from_json(&reparsed).unwrap() {
        Report::Factorize(back) => {
            assert_eq!(back.a.shape(), a_shape);
            assert_eq!(back.iters_run, iters_run);
            assert!((back.rel_error - rel_error).abs() < 1e-6);
            assert_eq!(back.traces.len(), 4);
            assert!(back.traces[0].total_seconds() > 0.0, "trace timings lost");
        }
        _ => panic!("report kind changed in roundtrip"),
    }
}

#[test]
fn model_select_report_roundtrips_through_json() {
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let planted = synthetic::block_tensor(16, 2, 2, 0.01, 123);
    let data = JobData::dense(planted.x);
    let cfg = RescalkConfig {
        k_min: 1,
        k_max: 3,
        perturbations: 4,
        rescal_iters: 120,
        regress_iters: 20,
        seed: 5,
        ..Default::default()
    };
    let report = engine.model_select(&data, &cfg).unwrap();
    let (k_opt, n_scores) = (report.k_opt, report.scores.len());

    let json = Report::ModelSelect(report).to_json();
    let reparsed = Json::parse(&json.to_string()).unwrap();
    assert_eq!(reparsed, json);
    match Report::from_json(&reparsed).unwrap() {
        Report::ModelSelect(back) => {
            assert_eq!(back.k_opt, k_opt);
            assert_eq!(back.scores.len(), n_scores);
        }
        _ => panic!("report kind changed in roundtrip"),
    }
}

#[test]
fn simulate_report_roundtrips_through_json() {
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let report = engine
        .simulate(SimSpec { machine: Machine::cpu_cluster(), scenario: SimScenario::Dense11Tb })
        .unwrap();
    let json = Report::Simulate(report.clone()).to_json();
    let reparsed = Json::parse(&json.to_string()).unwrap();
    assert_eq!(reparsed, json);
    match Report::from_json(&reparsed).unwrap() {
        Report::Simulate(back) => assert_eq!(back, report),
        _ => panic!("report kind changed in roundtrip"),
    }
}

#[test]
fn engine_rejects_invalid_grids_with_errors() {
    let e = Engine::new(EngineConfig::new(12)).unwrap_err();
    assert!(e.to_string().contains("perfect square"), "{e}");
    let e = Engine::new(EngineConfig::new(0)).unwrap_err();
    assert!(e.to_string().contains(">= 1"), "{e}");
}

#[test]
fn engine_rejects_unbuildable_backends_at_construction() {
    let cfg = EngineConfig::new(4).with_backend(drescal::backend::BackendSpec::Xla {
        artifact_dir: "/nonexistent/drescal-artifacts".to_string(),
    });
    let e = Engine::new(cfg).unwrap_err();
    assert!(e.to_string().contains("backend build failed"), "{e}");
}

#[test]
fn sparse_jobs_run_on_the_engine() {
    let mut engine = Engine::new(EngineConfig::new(4).with_trace(true)).unwrap();
    let xs = synthetic::sparse_planted(16, 2, 2, 0.2, 77);
    let data = JobData::sparse(xs);
    let report = engine.factorize(&data, &RescalOptions::new(2, 30), 5).unwrap();
    assert_eq!(report.a.shape(), (16, 2));
    assert!(report.rel_error.is_finite());
    let sparse_bytes: usize = report
        .traces
        .iter()
        .map(|t| t.bytes(drescal::comm::CommOp::MatrixMulSparse))
        .sum();
    assert!(sparse_bytes > 0, "sparse path not exercised");
}
