//! Integration: AOT artifacts (JAX+Pallas → HLO text) loaded and executed
//! through the PJRT runtime must match the native backend bit-for-tolerance.
//!
//! Requires the `pjrt` feature (the default stub runtime serves no
//! executables, so these assertions would fail even with artifacts on
//! disk) and `make artifacts` (skips gracefully when artifacts/ is
//! absent so `cargo test` works on a fresh checkout).
#![cfg(feature = "pjrt")]

use drescal::backend::{native::NativeBackend, xla::XlaBackend, Backend};
use drescal::rng::Rng;
use drescal::runtime::Runtime;
use drescal::tensor::Mat;
use drescal::testing::assert_close;

fn artifact_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn runtime_loads_all_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    assert!(!rt.is_empty());
    println!("loaded {} executables on {}", rt.len(), rt.platform());
}

#[test]
fn xla_matches_native_on_manifest_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let mut xla = XlaBackend::new(&dir).expect("backend");
    let mut native = NativeBackend::new();
    let mut rng = Rng::new(42);
    let manifest = drescal::runtime::Manifest::load(std::path::Path::new(&dir)).unwrap();
    let mut tested = 0;
    for entry in &manifest.entries {
        let inputs: Vec<Mat> = entry
            .shapes
            .iter()
            .map(|&(r, c)| Mat::random_uniform(r, c, 0.01, 1.0, &mut rng))
            .collect();
        let refs: Vec<&Mat> = inputs.iter().collect();
        if entry.kind == "slice_segment" {
            // fused 4-output segment: check against the composed native ops
            let outs = xla
                .runtime()
                .execute_multi(&entry.kind, &refs)
                .expect("execute_multi")
                .expect("artifact should match its own manifest shapes");
            assert_eq!(outs.len(), 4);
            let (r_t, ata, atxa, xa, a_row) =
                (&inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4]);
            let mut r_new = r_t.clone();
            let deno_r = ata.matmul(&r_t.matmul(ata));
            drescal::tensor::ops::mu_update(&mut r_new, atxa, &deno_r, 1e-16);
            assert_close(outs[0].as_slice(), r_new.as_slice(), 1e-3);
            assert_close(outs[1].as_slice(), xa.matmul_t(&r_new).as_slice(), 1e-3);
            let ar = a_row.matmul(&r_new);
            assert_close(outs[2].as_slice(), ar.as_slice(), 1e-3);
            let mut deno = a_row.matmul_t(&r_new).matmul(&ata.matmul(&r_new));
            deno.add_assign(&ar.matmul(&ata.matmul_t(&r_new)));
            assert_close(outs[3].as_slice(), deno.as_slice(), 1e-3);
            tested += 1;
            continue;
        }
        let got = xla
            .runtime()
            .execute(&entry.kind, &refs)
            .expect("execute")
            .expect("artifact should match its own manifest shapes");
        let want = match entry.kind.as_str() {
            "matmul" => native.matmul(&inputs[0], &inputs[1]),
            "t_matmul" => native.t_matmul(&inputs[0], &inputs[1]),
            "matmul_t" => native.matmul_t(&inputs[0], &inputs[1]),
            "gram" => native.gram(&inputs[0]),
            "r_update" => {
                let mut r = inputs[0].clone();
                let rata = inputs[0].matmul(&inputs[1]);
                let deno = inputs[1].matmul(&rata);
                drescal::tensor::ops::mu_update(&mut r, &inputs[2], &deno, 1e-16);
                r
            }
            other => panic!("unknown op kind {other}"),
        };
        assert_close(got.as_slice(), want.as_slice(), 1e-4);
        tested += 1;
    }
    assert!(tested >= 9, "expected a full op set, tested {tested}");
    println!("verified {tested} artifacts against native");
}

#[test]
fn xla_backend_falls_back_on_unknown_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let mut xla = XlaBackend::new(&dir).expect("backend");
    let mut rng = Rng::new(7);
    // a deliberately odd shape not in any manifest
    let a = Mat::random_uniform(13, 5, 0.0, 1.0, &mut rng);
    let b = Mat::random_uniform(5, 11, 0.0, 1.0, &mut rng);
    let got = xla.matmul(&a, &b);
    assert_close(got.as_slice(), a.matmul(&b).as_slice(), 1e-5);
    assert!(xla.fallbacks > 0);
}

#[test]
fn xla_backend_hits_artifacts_for_manifest_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let mut xla = XlaBackend::new(&dir).expect("backend");
    let manifest = drescal::runtime::Manifest::load(std::path::Path::new(&dir)).unwrap();
    // pick a gram entry and call through the Backend trait
    let entry = manifest.entries.iter().find(|e| e.kind == "gram").expect("gram artifact");
    let (r, c) = entry.shapes[0];
    let mut rng = Rng::new(9);
    let a = Mat::random_uniform(r, c, 0.0, 1.0, &mut rng);
    let got = xla.gram(&a);
    assert!(xla.hits >= 1, "artifact path not taken");
    assert_close(got.as_slice(), a.gram().as_slice(), 1e-4);
}
