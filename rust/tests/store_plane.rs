//! Storage/ingestion-plane integration: triples → shards → rank-resident
//! tiles → factors → named answers.
//!
//! Covers the acceptance criteria of the storage plane:
//! * ingest → `DatasetSpec::File` → train parity: **bit-identical**
//!   factors vs the same corpus passed inline as `JobData`, across grid
//!   sizes (1×1 and 2×2) and dense + sparse layouts;
//! * re-sharding: a corpus ingested at one grid size trains at another;
//! * per-rank shard reads only, dense tiles memory-mapped zero-copy
//!   (counter-asserted through `EngineStats` and `store::stats`);
//! * corrupt/truncated shards surface as typed errors that neither
//!   panic nor poison the rank pool (fuzz-style bit-flips);
//! * the `dataset_cache_bytes` LRU budget evicts and rebuilds tiles,
//!   counter-asserted like `tile_builds`;
//! * interned names ride ingest → export → serve, end to end.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use drescal::engine::{DatasetSpec, Engine, EngineConfig, Report};
use drescal::rescal::RescalOptions;
use drescal::rng::Rng;
use drescal::serve::QueryEngine;
use drescal::serve::Query;
use drescal::store::{self, IngestOptions, StoreManifest};

/// `store::stats` counters are process-global and the test harness runs
/// tests concurrently; counter-asserting tests serialize on this.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("drescal_store_plane_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Deterministic toy knowledge graph: every entity/relation id appears,
/// so the interned dictionaries have a known size.
fn write_triples(path: &Path, n: usize, m: usize, count: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let mut text = String::new();
    // guarantee every name appears at least once (ids 0..n, 0..m)
    for i in 0..n {
        text.push_str(&format!("e{i}\tr{}\te{}\n", i % m, (i + 1) % n));
    }
    for _ in 0..count {
        text.push_str(&format!(
            "e{}\tr{}\te{}\t{:.3}\n",
            rng.below(n),
            rng.below(m),
            rng.below(n),
            0.1 + rng.uniform_f32()
        ));
    }
    std::fs::write(path, text).unwrap();
}

fn ingest(dir: &Path, input: &Path, tag: &str, grid: usize, dense: bool) -> StoreManifest {
    let out = dir.join(format!("corpus_{tag}"));
    let report = store::ingest_triples_file(
        input,
        &out,
        &IngestOptions { grid, dense, source: input.display().to_string(), ..IngestOptions::default() },
    )
    .unwrap();
    StoreManifest::load(&report.manifest_path).unwrap()
}

/// The headline parity guarantee: factorizing a corpus loaded via
/// `DatasetSpec::File` produces **bit-identical** factors to the same
/// data passed inline as `JobData` — across engine grids 1×1 and 2×2,
/// for both sparse and dense layouts, including the re-sharding path
/// (corpus ingested at grid 2, trained at grid 1).
#[test]
fn ingest_train_parity_is_bit_identical_across_grids() {
    let _g = lock();
    let dir = tmp("parity");
    let input = dir.join("kg.tsv");
    write_triples(&input, 18, 2, 250, 7);
    for dense in [false, true] {
        let man = ingest(&dir, &input, &format!("parity_{dense}"), 2, dense);
        let inline = store::read_dataset_inline(&man).unwrap();
        for p in [1usize, 4] {
            let mut engine = Engine::new(EngineConfig::new(p)).unwrap();
            let from_file = engine
                .load_dataset(DatasetSpec::File(std::sync::Arc::new(man.clone())))
                .unwrap();
            let from_inline = engine.load_dataset(inline.clone()).unwrap();
            let opts = RescalOptions::new(3, 40);
            let a = engine.factorize(from_file, &opts, 11).unwrap();
            let b = engine.factorize(from_inline, &opts, 11).unwrap();
            assert_eq!(
                a.a.as_slice(),
                b.a.as_slice(),
                "A factors differ (dense={dense}, p={p})"
            );
            for t in 0..a.r.m() {
                assert_eq!(
                    a.r.slice(t).as_slice(),
                    b.r.slice(t).as_slice(),
                    "R slice {t} differs (dense={dense}, p={p})"
                );
            }
            assert_eq!(a.rel_error.to_bits(), b.rel_error.to_bits());
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Locality, counter-asserted: loading a File dataset on a matching-grid
/// engine performs exactly p tile builds and p shard reads (each rank
/// touches only its own shard; the leader reads just the manifest), and
/// dense tiles stay memory-mapped zero-copy on unix.
#[test]
fn ranks_read_only_their_own_shards_and_dense_tiles_are_mapped() {
    let _g = lock();
    let dir = tmp("local");
    let input = dir.join("kg.tsv");
    write_triples(&input, 16, 2, 200, 9);
    let man = ingest(&dir, &input, "local", 2, true);
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let before_store = store::stats::snapshot();
    assert_eq!(engine.stats().tile_builds, 0);
    let handle = engine.load_dataset(DatasetSpec::from(man)).unwrap();
    let after_store = store::stats::snapshot();
    let stats = engine.stats();
    assert_eq!(stats.tile_builds, 4, "one tile build per rank");
    assert_eq!(
        after_store.shard_reads - before_store.shard_reads,
        4,
        "each rank reads exactly its own shard"
    );
    assert_eq!(
        after_store.spliced_tiles, before_store.spliced_tiles,
        "matching grids must not re-shard"
    );
    let info = engine.dataset_info(handle).unwrap();
    assert!(!info.sparse);
    assert_eq!((info.n, info.m), (16, 2));
    assert!(info.resident_bytes > 0);
    if cfg!(unix) && cfg!(target_endian = "little") {
        assert_eq!(
            after_store.mapped_tiles - before_store.mapped_tiles,
            4,
            "dense tiles at a matching grid must be mmap windows"
        );
        assert!(after_store.mapped_bytes > before_store.mapped_bytes);
    }
    // jobs run straight off the mapped tiles
    let report = engine.factorize(handle, &RescalOptions::new(3, 30), 5).unwrap();
    assert!(report.rel_error.is_finite());
    // ...and tiles were not rebuilt or re-read by the job
    assert_eq!(engine.stats().tile_builds, 4);
    assert_eq!(store::stats::snapshot().shard_reads - before_store.shard_reads, 4);
    std::fs::remove_dir_all(&dir).ok();
}

/// A corpus ingested once loads on engines of any grid size: grid
/// mismatches re-shard at load time (counter-asserted), and the spliced
/// tiles train to the same factors as a matching-grid load.
#[test]
fn resharding_loads_any_grid_from_one_ingest() {
    let _g = lock();
    let dir = tmp("reshard");
    let input = dir.join("kg.tsv");
    write_triples(&input, 15, 2, 220, 13);
    let man1 = ingest(&dir, &input, "g1", 1, false);
    let man2 = ingest(&dir, &input, "g2", 2, false);
    let opts = RescalOptions::new(3, 40);
    // grid-1 corpus on a 2×2 engine (split) vs grid-2 corpus direct
    let before = store::stats::snapshot();
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let split = engine.load_dataset(DatasetSpec::from(man1.clone())).unwrap();
    assert!(
        store::stats::snapshot().spliced_tiles > before.spliced_tiles,
        "grid mismatch must take the re-sharding path"
    );
    let direct = engine.load_dataset(DatasetSpec::from(man2.clone())).unwrap();
    let a = engine.factorize(split, &opts, 3).unwrap();
    let b = engine.factorize(direct, &opts, 3).unwrap();
    assert_eq!(a.a.as_slice(), b.a.as_slice(), "split and direct loads must agree");
    // grid-2 corpus on a 1×1 engine (merge)
    let mut engine1 = Engine::new(EngineConfig::new(1)).unwrap();
    let merged = engine1.load_dataset(DatasetSpec::from(man2)).unwrap();
    let c = engine1.factorize(merged, &opts, 3).unwrap();
    let mut engine1b = Engine::new(EngineConfig::new(1)).unwrap();
    let one = engine1b.load_dataset(DatasetSpec::from(man1)).unwrap();
    let d = engine1b.factorize(one, &opts, 3).unwrap();
    assert_eq!(c.a.as_slice(), d.a.as_slice(), "merge load must agree with direct");
    std::fs::remove_dir_all(&dir).ok();
}

/// Fuzz-style corruption: bit-flips and truncations anywhere in a shard
/// file surface as typed errors; the engine rolls back the partial load
/// and the pool keeps serving jobs.
#[test]
fn corrupt_shards_are_typed_errors_and_do_not_poison_the_pool() {
    let _g = lock();
    let dir = tmp("corrupt");
    let input = dir.join("kg.tsv");
    write_triples(&input, 12, 2, 120, 21);
    for dense in [false, true] {
        let man = ingest(&dir, &input, &format!("corrupt_{dense}"), 2, dense);
        let shard_path = man.shard_path(man.shard(1, 1).unwrap());
        let clean = std::fs::read(&shard_path).unwrap();
        let mut engine = Engine::new(EngineConfig::new(4)).unwrap();

        // bit-flips across the file: header magic, header dims, payload
        let positions =
            [0usize, 9, 17, 41, 70, clean.len() / 2, clean.len() - 1];
        for &pos in positions.iter().filter(|&&p| p < clean.len()) {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&shard_path, &bad).unwrap();
            let e = engine
                .load_dataset(DatasetSpec::from(man.clone()))
                .expect_err(&format!("bit-flip at byte {pos} must fail (dense={dense})"));
            let msg = e.to_string();
            assert!(
                msg.contains("rank"),
                "error must name the failing rank: {msg}"
            );
        }

        // truncations at several points (mid-header, just past the
        // 64-byte header, and mid-payload)
        for cut in [10usize, 65, clean.len() - 3] {
            let cut = cut.min(clean.len() - 1);
            std::fs::write(&shard_path, &clean[..cut]).unwrap();
            assert!(
                engine.load_dataset(DatasetSpec::from(man.clone())).is_err(),
                "truncation to {cut} bytes must fail"
            );
        }

        // a missing shard file
        std::fs::remove_file(&shard_path).unwrap();
        assert!(engine.load_dataset(DatasetSpec::from(man.clone())).is_err());

        // restore: the pool survived every failure, the partial loads
        // were rolled back, and a clean load + job still works
        std::fs::write(&shard_path, &clean).unwrap();
        assert_eq!(engine.stats().datasets_resident, 0, "failed loads must roll back");
        let handle = engine.load_dataset(DatasetSpec::from(man)).unwrap();
        let report = engine.factorize(handle, &RescalOptions::new(2, 10), 1).unwrap();
        assert!(report.rel_error.is_finite());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The `dataset_cache_bytes` budget: loads beyond the budget evict the
/// LRU dataset's tiles (registration survives), and the next job on an
/// evicted handle rebuilds them — all counter-asserted like
/// `tile_builds`.
#[test]
fn dataset_cache_budget_evicts_and_rebuilds_lru() {
    use drescal::data::synthetic::SyntheticSpec;
    // one 16×16×2 dense tile on a 1-rank engine = 2048 bytes resident
    let tile_bytes = 16 * 16 * 2 * 4;
    let mut engine = Engine::new(
        EngineConfig::new(1).with_dataset_cache_bytes(tile_bytes + tile_bytes / 2),
    )
    .unwrap();
    let a = engine.load_dataset(SyntheticSpec::dense(16, 2, 2, 1)).unwrap();
    let s = engine.stats();
    assert_eq!((s.tile_builds, s.tile_evictions), (1, 0));
    assert_eq!(s.resident_bytes, tile_bytes);

    // loading B blows the budget: A (the LRU) is evicted but stays
    // registered
    let b = engine.load_dataset(SyntheticSpec::dense(16, 2, 2, 2)).unwrap();
    let s = engine.stats();
    assert_eq!(s.tile_builds, 2);
    assert_eq!(s.tile_evictions, 1, "A must be evicted by B's load");
    assert_eq!(s.resident_bytes, tile_bytes, "only B resident");
    assert_eq!(s.datasets_resident, 2, "eviction keeps the registration");
    let a_info = engine.dataset_info(a).expect("eviction keeps the registration");
    assert_eq!(a_info.resident_bytes, 0, "evicted tiles must not be double-counted");

    // a job on the evicted handle transparently rebuilds its tiles (and
    // evicts B in turn)
    let report = engine.factorize(a, &RescalOptions::new(2, 10), 1).unwrap();
    assert!(report.rel_error.is_finite());
    let s = engine.stats();
    assert_eq!(s.tile_builds, 3, "evicted handle must rebuild exactly once");
    assert_eq!(s.tile_evictions, 2, "B evicted while A rebuilt");

    // repeated jobs on the now-resident handle rebuild nothing
    engine.factorize(a, &RescalOptions::new(2, 10), 2).unwrap();
    engine.factorize(a, &RescalOptions::new(2, 10), 3).unwrap();
    assert_eq!(engine.stats().tile_builds, 3);

    // B works too, and unbounded engines never evict
    engine.factorize(b, &RescalOptions::new(2, 10), 1).unwrap();
    let mut unbounded = Engine::new(EngineConfig::new(1)).unwrap();
    let x = unbounded.load_dataset(SyntheticSpec::dense(16, 2, 2, 3)).unwrap();
    let y = unbounded.load_dataset(SyntheticSpec::dense(16, 2, 2, 4)).unwrap();
    unbounded.factorize(x, &RescalOptions::new(2, 5), 1).unwrap();
    unbounded.factorize(y, &RescalOptions::new(2, 5), 1).unwrap();
    assert_eq!(unbounded.stats().tile_evictions, 0);
    assert_eq!(unbounded.stats().resident_bytes, 2 * tile_bytes);
}

/// Names ride the whole pipe: ingest interns them, `export_model_for`
/// attaches them, the persisted artifact round-trips them, and the
/// query layer resolves them — so served answers are name-resolvable
/// end to end.
#[test]
fn interned_names_flow_from_ingest_to_served_answers() {
    let _g = lock();
    let dir = tmp("names");
    let input = dir.join("toy.tsv");
    std::fs::write(
        &input,
        "alice\tknows\tbob\n\
         bob\tknows\tcarol\n\
         carol\tknows\talice\n\
         alice\tlikes\tcarol\n\
         bob\tlikes\talice\n",
    )
    .unwrap();
    let man = ingest(&dir, &input, "names", 1, false);
    assert_eq!(man.entities, vec!["alice", "bob", "carol"]);
    assert_eq!(man.relations, vec!["knows", "likes"]);

    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let data = engine.load_dataset(DatasetSpec::from(man)).unwrap();
    let report = engine.factorize(data, &RescalOptions::new(2, 60), 5).unwrap();
    let model = engine.export_model_for(&Report::Factorize(report), data).unwrap();
    assert_eq!(model.entity_names().unwrap(), &["alice", "bob", "carol"]);
    assert_eq!(model.relation_names().unwrap(), &["knows", "likes"]);

    // persist → reload → resolve by name
    let model_path = dir.join("model.json");
    model.save(&model_path).unwrap();
    let reloaded = drescal::serve::FactorModel::load(&model_path).unwrap();
    assert_eq!(reloaded.resolve_entity("carol").unwrap(), 2);
    assert_eq!(reloaded.resolve_relation("likes").unwrap(), 1);
    assert!(reloaded.resolve_entity("mallory").is_err());

    let s = reloaded.resolve_entity("alice").unwrap();
    let r = reloaded.resolve_relation("knows").unwrap();
    let mut qe = QueryEngine::new(reloaded);
    let answer = qe.query(Query::TopObjects { s, r, top: 2 }).unwrap();
    match answer {
        drescal::serve::Answer::TopK(hits) => {
            assert_eq!(hits.len(), 2);
            // every hit maps back to a name
            for h in &hits {
                assert!(qe.model().entity_names().unwrap().get(h.entity).is_some());
            }
        }
        other => panic!("expected top-k hits, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
