//! Scaling invariants: the distributed algorithm's *results* must not
//! depend on the grid size, and its communication volume must follow the
//! §5 complexity analysis.

use drescal::backend::native::NativeBackend;
use drescal::backend::Workspace;
use drescal::comm::grid::run_on_grid;
use drescal::comm::{CommOp, Trace};
use drescal::data::synthetic;
use drescal::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use drescal::rescal::{LocalTile, ModelKind, RescalOptions};
use drescal::tensor::{Mat, Tensor3};

fn run_p(x: &Tensor3, p: usize, k: usize, iters: usize) -> (Mat, f32, Vec<Trace>) {
    let n = x.n1();
    // shared full-A init so every grid size starts identically
    let mut rng = drescal::rng::Rng::new(77);
    let a0 = std::sync::Arc::new(Mat::random_uniform(n, k, 0.01, 1.0, &mut rng));
    let r0 = std::sync::Arc::new(Tensor3::random_uniform(k, k, x.m(), 0.01, 1.0, &mut rng));
    let results = run_on_grid(p, |ctx| {
        let (r0_, r1) = ctx.grid.chunk(n, ctx.row);
        let (c0, c1) = ctx.grid.chunk(n, ctx.col);
        let tile = LocalTile::Dense(x.tile(r0_, r1, c0, c1));
        let cfg = DistRescalConfig {
            opts: RescalOptions::new(k, iters),
            init: DistInit::Given(a0.clone(), r0.clone()),
            n,
            model: ModelKind::Rescal,
        };
        let mut backend = NativeBackend::new();
        let mut ws = Workspace::new();
        let mut trace = Trace::new();
        let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
            .expect("in-process rescal_rank");
        (ctx.row, ctx.col, out, trace)
    });
    let grid = drescal::comm::Grid::new(p);
    let mut a = Mat::zeros(n, k);
    let mut err = 0.0;
    let mut traces = Vec::new();
    for (row, col, res, trace) in results {
        if row == col {
            let (s, _) = grid.chunk(n, row);
            for i in 0..res.a_row.rows() {
                for j in 0..k {
                    a[(s + i, j)] = res.a_row[(i, j)];
                }
            }
            err = res.rel_error;
        }
        traces.push(trace);
    }
    (a, err, traces)
}

#[test]
fn results_independent_of_grid_size() {
    let planted = synthetic::planted_tensor(24, 2, 3, 0.0, 1100);
    let (a1, e1, _) = run_p(&planted.x, 1, 3, 12);
    let (a4, e4, _) = run_p(&planted.x, 4, 3, 12);
    let (a9, e9, _) = run_p(&planted.x, 9, 3, 12);
    drescal::testing::assert_close(a4.as_slice(), a1.as_slice(), 1e-3);
    drescal::testing::assert_close(a9.as_slice(), a1.as_slice(), 1e-3);
    assert!((e4 - e1).abs() < 1e-3);
    assert!((e9 - e1).abs() < 1e-3);
}

/// §5.1.2: per-iteration reduced bytes per rank scale as n/√p·k — the
/// *local* communication payload shrinks with the grid even though the
/// number of collectives grows.
#[test]
fn communication_volume_follows_complexity() {
    let planted = synthetic::planted_tensor(32, 2, 4, 0.0, 1101);
    let (_a4, _e4, tr4) = run_p(&planted.x, 4, 4, 3);
    let (_a16, _e16, tr16) = run_p(&planted.x, 16, 4, 3);
    let reduce_bytes = |tr: &Vec<Trace>| -> f64 {
        let total: usize = tr
            .iter()
            .map(|t| t.bytes(CommOp::RowReduce) + t.bytes(CommOp::ColumnReduce))
            .sum();
        total as f64 / tr.len() as f64
    };
    let b4 = reduce_bytes(&tr4);
    let b16 = reduce_bytes(&tr16);
    // n/√p halves from q=2 to q=4, so the XA payloads halve; k×k terms are
    // constant — expect a ratio comfortably above 1 but below 2
    let ratio = b4 / b16;
    assert!(
        ratio > 1.2 && ratio < 2.2,
        "per-rank reduce bytes p=4 {b4} vs p=16 {b16} (ratio {ratio})"
    );
}

/// Strong-scaling compute: per-rank matmul bytes drop by ~p (the tile area).
#[test]
fn compute_volume_drops_with_p() {
    let planted = synthetic::planted_tensor(32, 2, 4, 0.0, 1102);
    let (_a, _e, tr1) = run_p(&planted.x, 1, 4, 3);
    let (_a4, _e4, tr4) = run_p(&planted.x, 4, 4, 3);
    let mm_bytes = |tr: &Vec<Trace>| -> f64 {
        let total: usize = tr.iter().map(|t| t.bytes(CommOp::MatrixMul)).sum();
        total as f64 / tr.len() as f64
    };
    let b1 = mm_bytes(&tr1);
    let b4 = mm_bytes(&tr4);
    let ratio = b1 / b4;
    assert!(ratio > 3.0 && ratio < 5.0, "tile bytes p=1 {b1} vs p=4 {b4} (ratio {ratio})");
}
