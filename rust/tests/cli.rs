//! CLI integration: drive the `drescal` binary end to end.

use std::process::Command;

fn drescal() -> Command {
    Command::new(env!("CARGO_BIN_EXE_drescal"))
}

fn run(args: &[&str]) -> (bool, String) {
    let out = drescal().args(args).output().expect("spawn drescal");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for sub in ["run", "model-select", "exascale", "artifacts"] {
        assert!(text.contains(sub), "help missing {sub}");
    }
}

#[test]
fn no_args_prints_help() {
    let out = drescal().output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn run_blocks_converges() {
    let (ok, text) = run(&[
        "run", "--data", "blocks", "--n", "32", "--m", "2", "--k-true", "3", "--k", "3",
        "--p", "4", "--iters", "200", "--seed", "5", "--trace",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("rel_error"), "{text}");
    // breakdown printed when tracing is requested
    assert!(text.contains("matrix_mul"), "{text}");
    // extract the error and check it converged
    let err: f32 = text
        .split("rel_error=")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .expect("parse rel_error");
    assert!(err < 0.15, "rel_error={err}");
}

#[test]
fn run_sparse_path() {
    let (ok, text) = run(&[
        "run", "--data", "synthetic", "--n", "48", "--m", "2", "--k-true", "3", "--k", "3",
        "--density", "0.05", "--p", "4", "--iters", "30", "--trace",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("matrix_mul_sparse"), "sparse path not traced: {text}");
}

#[test]
fn tracing_is_opt_in() {
    let (ok, text) = run(&[
        "run", "--data", "blocks", "--n", "16", "--m", "2", "--k-true", "2", "--k", "2",
        "--p", "1", "--iters", "20",
    ]);
    assert!(ok, "{text}");
    // without --trace no per-op breakdown is printed
    assert!(!text.contains("matrix_mul"), "breakdown printed untraced: {text}");
}

#[test]
fn json_report_is_parseable() {
    let (ok, text) = run(&[
        "run", "--data", "blocks", "--n", "16", "--m", "2", "--k-true", "2", "--k", "2",
        "--p", "1", "--iters", "20", "--json",
    ]);
    assert!(ok, "{text}");
    let json_line = text
        .lines()
        .find(|l| l.starts_with('{'))
        .expect("no JSON report line in output");
    assert!(json_line.contains("\"kind\":\"factorize\""), "{json_line}");
    assert!(json_line.contains("\"rel_error\""), "{json_line}");
}

#[test]
fn validation_errors_are_typed() {
    // non-square grid
    let (ok, text) = run(&["run", "--p", "8"]);
    assert!(!ok);
    assert!(text.contains("perfect square"), "{text}");
    // bad k range
    let (ok, text) = run(&["model-select", "--k-min", "5", "--k-max", "3"]);
    assert!(!ok);
    assert!(text.contains("bad k range"), "{text}");
    // unknown flag for the subcommand
    let (ok, text) = run(&["exascale", "--k", "4"]);
    assert!(!ok);
    assert!(text.contains("unknown flag"), "{text}");
}

#[test]
fn model_select_recovers_k() {
    let (ok, text) = run(&[
        "model-select", "--data", "blocks", "--n", "24", "--m", "2", "--k-true", "3",
        "--k-min", "2", "--k-max", "4", "--perturbations", "5", "--iters", "200",
        "--p", "4", "--seed", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("k_opt = 3"), "{text}");
    assert!(text.contains("matches the dataset's ground truth"), "{text}");
}

#[test]
fn exascale_replay_runs() {
    let (ok, text) = run(&["exascale", "--machine", "cpu"]);
    assert!(ok, "{text}");
    assert!(text.contains("Fig 13a"), "{text}");
    assert!(text.contains("comm%"), "{text}");
}

#[test]
fn artifacts_lists_manifest() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let (ok, text) = run(&["artifacts", "--artifacts", dir.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("slice_segment"), "{text}");
    assert!(text.contains("gram"), "{text}");
}

#[test]
fn config_file_round_trip() {
    let dir = std::env::temp_dir().join(format!("drescal_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("run.json");
    std::fs::write(
        &cfg,
        r#"{"data": "blocks", "n": 24, "m": 2, "k-true": 2, "k": 2, "p": 1, "iters": 50}"#,
    )
    .unwrap();
    let (ok, text) = run(&["run", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("n=24"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn export_then_query_round_trip() {
    let dir = std::env::temp_dir().join(format!("drescal_cli_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("model.json");
    let model_path = model.to_str().unwrap();
    // train a small blocks tensor and persist the factor model
    let (ok, text) = run(&[
        "export", "--data", "blocks", "--n", "24", "--m", "2", "--k-true", "3", "--k", "3",
        "--p", "4", "--iters", "100", "--model", model_path,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("exported factor model"), "{text}");
    assert!(model.exists(), "model artifact not written");
    // top-k objects from the saved artifact (no engine in this process)
    let (ok, text) = run(&["query", "--model", model_path, "--s", "0", "--r", "0", "--top", "3"]);
    assert!(ok, "{text}");
    assert!(text.contains("top objects for (s=0, r=0, ?)"), "{text}");
    // pointwise score, JSON form
    let (ok, text) = run(&[
        "query", "--model", model_path, "--s", "0", "--o", "1", "--r", "0", "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"kind\":\"score\""), "{text}");
    // typed errors: out-of-range entity, missing anchors
    let (ok, text) = run(&["query", "--model", model_path, "--s", "999", "--r", "0"]);
    assert!(!ok);
    assert!(text.contains("out of range"), "{text}");
    let (ok, text) = run(&["query", "--model", model_path]);
    assert!(!ok);
    assert!(text.contains("--s and/or --o"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_smoke_tracks_a_trajectory() {
    let dir = std::env::temp_dir().join(format!("drescal_cli_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("BENCH.json");
    let out_path = out.to_str().unwrap();
    // first run: no baseline yet
    let (ok, text) = run(&["bench", "--iters", "1", "--p", "1", "--out", out_path]);
    assert!(ok, "{text}");
    assert!(text.contains("serve_topk_batched"), "serve section missing: {text}");
    assert!(text.contains("kernel_packed_gemm_512"), "kernel section missing: {text}");
    assert!(text.contains("kernel_legacy_gemm_512"), "legacy comparison missing: {text}");
    assert!(text.contains("packed kernel speedup"), "{text}");
    assert!(text.contains("no baseline"), "{text}");
    // second run: self-baselines against the first output, prints deltas
    let (ok, text) = run(&[
        "bench", "--iters", "1", "--p", "1", "--out", out_path, "--max-regression", "1000",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("perf trajectory"), "{text}");
    assert!(text.contains("ratio"), "{text}");
    // an absurdly tight gate (with the noise floor disabled) trips the
    // typed regression error, and the failed run keeps the baseline
    let before = std::fs::read_to_string(&out).unwrap();
    let (ok, text) = run(&[
        "bench", "--iters", "1", "--p", "1", "--out", out_path, "--max-regression",
        "0.0000001", "--gate-floor", "0",
    ]);
    assert!(!ok, "a 1e-7x regression limit must fail");
    assert!(text.contains("perf regression"), "{text}");
    assert_eq!(
        std::fs::read_to_string(&out).unwrap(),
        before,
        "a gated run must not overwrite its own baseline"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The full storage-plane flow on the real binary: synthesize triples,
/// ingest at grid 1, train from the manifest on a 2×2 engine (re-shard
/// at load), export a named model, and query it by name.
#[test]
fn ingest_train_query_by_name_flow() {
    let dir = std::env::temp_dir().join(format!("drescal_cli_ingest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let triples = dir.join("kg.tsv");
    let mut text = String::new();
    for i in 0..20 {
        for j in 0..20 {
            if (i + j) % 3 == 0 {
                text.push_str(&format!("e{i}\tknows\te{j}\n"));
            }
            if (i * j) % 7 == 1 {
                text.push_str(&format!("e{i}\tlikes\te{j}\n"));
            }
        }
    }
    std::fs::write(&triples, text).unwrap();
    let corpus = dir.join("corpus");
    let manifest = corpus.join("manifest.json");
    let (ok, out) = run(&[
        "ingest", "--input", triples.to_str().unwrap(), "--out", corpus.to_str().unwrap(),
        "--grid", "1",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("ingested"), "{out}");
    assert!(manifest.exists(), "manifest not written");

    let file_arg = format!("file:{}", manifest.display());
    let (ok, out) = run(&[
        "run", "--data", &file_arg, "--p", "4", "--k", "3", "--iters", "40",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("rel_error"), "{out}");

    let model = dir.join("kg_model.json");
    let (ok, out) = run(&[
        "export", "--data", &file_arg, "--p", "4", "--k", "3", "--iters", "40",
        "--model", model.to_str().unwrap(),
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("named"), "exported model must carry names: {out}");

    // query by entity/relation *name*; answers resolve back to names
    let (ok, out) = run(&[
        "query", "--model", model.to_str().unwrap(), "--s", "e1", "--r", "knows",
        "--top", "3",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("top objects"), "{out}");
    assert!(out.contains("(e"), "hits must be name-labelled: {out}");
    // unknown names are typed errors
    let (ok, out) = run(&[
        "query", "--model", model.to_str().unwrap(), "--s", "mallory", "--r", "knows",
    ]);
    assert!(!ok);
    assert!(out.contains("unknown entity name"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ingest_errors_are_typed() {
    let (ok, out) = run(&["ingest"]);
    assert!(!ok);
    assert!(out.contains("--input"), "{out}");
    let (ok, out) = run(&["run", "--data", "file:/nonexistent/manifest.json", "--p", "1"]);
    assert!(!ok);
    assert!(out.contains("manifest"), "{out}");
}

#[test]
fn bad_flags_are_reported() {
    let (ok, text) = run(&["run", "--p", "notanumber"]);
    assert!(!ok);
    assert!(text.contains("--p expects an integer"), "{text}");
    let (ok, text) = run(&["run", "--backend", "cuda"]);
    assert!(!ok);
    assert!(text.contains("unknown backend"), "{text}");
}
