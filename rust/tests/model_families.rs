//! Model-family integration: the trait-dispatched MU rules must match
//! independent naive references, keep their convergence guarantees, and
//! survive the full train → export → persist → serve lifecycle — for
//! every family, on every grid shape, tile storage, and transport.

use std::sync::Arc;

use drescal::backend::native::NativeBackend;
use drescal::backend::Workspace;
use drescal::comm::grid::run_on_grid;
use drescal::comm::transport::tcp::{loopback_meshes, rank_ctx_from_mesh, TcpConfig};
use drescal::comm::{Grid, RankCtx, Trace};
use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{Engine, EngineConfig, Report};
use drescal::json::Json;
use drescal::model_selection::{InitStrategy, RescalkConfig};
use drescal::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use drescal::rescal::{LocalTile, ModelKind, RescalOptions};
use drescal::rng::Rng;
use drescal::serve::{Answer, FactorModel, Query, QueryEngine};
use drescal::tensor::{Csr, Mat, Tensor3};

/// Shared full-size initial factors for a family (`r0` has the family's
/// core shape), so independent runs start identically.
fn given_init(
    n: usize,
    k: usize,
    m: usize,
    kind: ModelKind,
    seed: u64,
) -> (Arc<Mat>, Arc<Tensor3>) {
    let mut rng = Rng::new(seed);
    let a0 = Mat::random_uniform(n, k, 0.01, 1.0, &mut rng);
    let r0 = Tensor3::random_uniform(kind.core_rows(k), k, m, 0.01, 1.0, &mut rng);
    (Arc::new(a0), Arc::new(r0))
}

/// Run one family through `rescal_rank` on an explicit set of rank
/// contexts (in-process or TCP — the same code path the engine drives),
/// returning `(row, col, a_row, rel_error)` per rank.
fn run_family_on(
    ctxs: Vec<RankCtx>,
    x: &Tensor3,
    kind: ModelKind,
    a0: &Arc<Mat>,
    r0: &Arc<Tensor3>,
    iters: usize,
) -> Vec<(usize, usize, Mat, f32)> {
    let n = x.n1();
    std::thread::scope(|s| {
        let handles: Vec<_> = ctxs
            .into_iter()
            .map(|ctx| {
                let (a0, r0) = (a0.clone(), r0.clone());
                s.spawn(move || {
                    let (rs, re) = ctx.grid.chunk(n, ctx.row);
                    let (cs, ce) = ctx.grid.chunk(n, ctx.col);
                    let tile = LocalTile::Dense(x.tile(rs, re, cs, ce));
                    let cfg = DistRescalConfig {
                        opts: RescalOptions::new(a0.cols(), iters),
                        init: DistInit::Given(a0, r0),
                        n,
                        model: kind,
                    };
                    let mut backend = NativeBackend::new();
                    let mut ws = Workspace::new();
                    let mut trace = Trace::disabled();
                    let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                        .expect("rescal_rank");
                    (ctx.row, ctx.col, out.a_row, out.rel_error)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Assemble the full A from the diagonal ranks of a grid run.
fn assemble_a(results: &[(usize, usize, Mat, f32)], n: usize, k: usize, p: usize) -> (Mat, f32) {
    let grid = Grid::new(p);
    let mut a = Mat::zeros(n, k);
    let mut err = 0.0;
    for (row, col, block, rel) in results {
        if row == col {
            let (s, _) = grid.chunk(n, *row);
            for i in 0..block.rows() {
                for j in 0..k {
                    a[(s + i, j)] = block[(i, j)];
                }
            }
            err = *rel;
        }
    }
    (a, err)
}

// ---------------------------------------------------------------------
// DistMult vs a naive dense-diagonal reference
// ---------------------------------------------------------------------

/// Textbook DistMult MU, written against full dense matrices with the
/// diagonal kept explicit — no shared code with the trait impl. Mirrors
/// the distributed schedule (per-slice d update under the *current*
/// iterate, A update from the summed terms, final column normalization
/// with `d_j ← d_j·s_j²`).
fn distmult_reference(
    x: &Tensor3,
    a0: &Mat,
    d0: &Tensor3,
    iters: usize,
    eps: f32,
) -> (Mat, Tensor3, f32) {
    let (n, k, m) = (a0.rows(), a0.cols(), x.m());
    let mut a = a0.clone();
    let mut d: Vec<Vec<f32>> =
        (0..m).map(|t| d0.slice(t).row(0).to_vec()).collect();
    for _ in 0..iters {
        // G = AᵀA
        let mut g = vec![vec![0.0f32; k]; k];
        for i in 0..n {
            for j1 in 0..k {
                for j2 in 0..k {
                    g[j1][j2] += a[(i, j1)] * a[(i, j2)];
                }
            }
        }
        let mut num_a = vec![vec![0.0f32; k]; n];
        let mut deno_a = vec![vec![0.0f32; k]; n];
        for t in 0..m {
            let xt = x.slice(t);
            // XA
            let mut xa = vec![vec![0.0f32; k]; n];
            for i in 0..n {
                for p in 0..n {
                    let v = xt[(i, p)];
                    for j in 0..k {
                        xa[i][j] += v * a[(p, j)];
                    }
                }
            }
            // d ← d ∘ diag(AᵀX_tA) / (d·(G∘G) + ε)
            let dt = &mut d[t];
            for j in 0..k {
                let mut num = 0.0f32;
                for i in 0..n {
                    num += a[(i, j)] * xa[i][j];
                }
                let mut deno = 0.0f32;
                for l in 0..k {
                    deno += dt[l] * g[l][j] * g[l][j];
                }
                dt[j] *= num / (deno + eps);
            }
            // A-update terms under the refreshed d:
            // num += X_tA·D + X_tᵀ(A·D), deno += 2·(A·D)(G·D)
            for i in 0..n {
                for j in 0..k {
                    num_a[i][j] += xa[i][j] * dt[j];
                    let mut acc = 0.0f32;
                    for l in 0..k {
                        acc += a[(i, l)] * dt[l] * g[l][j] * dt[j];
                    }
                    deno_a[i][j] += 2.0 * acc;
                }
            }
            for i in 0..n {
                for p in 0..n {
                    let v = xt[(p, i)];
                    for j in 0..k {
                        num_a[i][j] += v * a[(p, j)] * dt[j];
                    }
                }
            }
        }
        for i in 0..n {
            for j in 0..k {
                a[(i, j)] *= num_a[i][j] / (deno_a[i][j] + eps);
            }
        }
    }
    // final normalization: unit columns, d absorbs s²
    let mut scales = vec![0.0f32; k];
    for j in 0..k {
        let mut sq = 0.0f32;
        for i in 0..n {
            sq += a[(i, j)] * a[(i, j)];
        }
        scales[j] = if sq > 0.0 { sq.sqrt() } else { 1.0 };
    }
    for i in 0..n {
        for j in 0..k {
            a[(i, j)] /= scales[j];
        }
    }
    for dt in &mut d {
        for j in 0..k {
            dt[j] *= scales[j] * scales[j];
        }
    }
    // ‖X − A·D_t·Aᵀ‖ / ‖X‖
    let mut res = 0.0f64;
    let mut norm = 0.0f64;
    for t in 0..m {
        let xt = x.slice(t);
        for i in 0..n {
            for o in 0..n {
                let mut rec = 0.0f32;
                for j in 0..k {
                    rec += a[(i, j)] * d[t][j] * a[(o, j)];
                }
                let diff = (xt[(i, o)] - rec) as f64;
                res += diff * diff;
                norm += (xt[(i, o)] as f64) * (xt[(i, o)] as f64);
            }
        }
    }
    let rel = (res.sqrt() / norm.sqrt().max(1e-300)) as f32;
    let d_tensor =
        Tensor3::from_slices(d.into_iter().map(|dt| Mat::from_vec(1, k, dt)).collect());
    (a, d_tensor, rel)
}

#[test]
fn distmult_trait_matches_naive_diagonal_reference() {
    let (n, m, k, iters) = (16, 2, 3, 8);
    let x = synthetic::planted_tensor(n, m, k, 0.0, 2200).x;
    let (a0, r0) = given_init(n, k, m, ModelKind::DistMult, 2201);
    let eps = RescalOptions::new(k, iters).eps;
    let (a_want, d_want, rel_want) = distmult_reference(&x, &a0, &r0, iters, eps);

    let results = run_family_on(
        RankCtx::create_all(1),
        &x,
        ModelKind::DistMult,
        &a0,
        &r0,
        iters,
    );
    let (a_got, rel_got) = assemble_a(&results, n, k, 1);
    for i in 0..n {
        for j in 0..k {
            let (got, want) = (a_got[(i, j)], a_want[(i, j)]);
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                "A[{i},{j}]: trait {got} vs reference {want}"
            );
        }
    }
    assert!(
        (rel_got - rel_want).abs() < 1e-3,
        "rel_error: trait {rel_got} vs reference {rel_want}"
    );
    // the reference must actually fit the planted tensor, or the
    // agreement above is vacuous
    assert!(rel_want < 0.5, "reference failed to descend: {rel_want}");
}

#[test]
fn distmult_results_independent_of_grid_and_tile_storage() {
    let (n, m, k, iters) = (20, 2, 3, 10);
    let mut rng = Rng::new(2300);
    // genuinely sparse data so the CSR path has structure to walk
    let sparse: Vec<Csr> = (0..m).map(|_| Csr::random(n, n, 0.15, &mut rng)).collect();
    let x = Tensor3::from_slices(sparse.iter().map(|s| s.to_dense()).collect());
    let (a0, r0) = given_init(n, k, m, ModelKind::DistMult, 2301);

    let g1 = run_family_on(RankCtx::create_all(1), &x, ModelKind::DistMult, &a0, &r0, iters);
    let (a1, e1) = assemble_a(&g1, n, k, 1);
    let g4 = run_family_on(RankCtx::create_all(4), &x, ModelKind::DistMult, &a0, &r0, iters);
    let (a4, e4) = assemble_a(&g4, n, k, 4);
    drescal::testing::assert_close(a4.as_slice(), a1.as_slice(), 1e-3);
    assert!((e4 - e1).abs() < 1e-3, "grid changed the answer: {e1} vs {e4}");

    // same data through the sparse tile on a 1×1 grid
    let results = run_on_grid(1, |ctx| {
        let tile = LocalTile::Sparse(sparse.clone());
        let cfg = DistRescalConfig {
            opts: RescalOptions::new(k, iters),
            init: DistInit::Given(a0.clone(), r0.clone()),
            n,
            model: ModelKind::DistMult,
        };
        let mut backend = NativeBackend::new();
        let mut ws = Workspace::new();
        let mut trace = Trace::disabled();
        rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
            .expect("sparse-tile rescal_rank")
    });
    let sp = &results[0];
    drescal::testing::assert_close(sp.a_row.as_slice(), a1.as_slice(), 1e-3);
    assert!((sp.rel_error - e1).abs() < 1e-3, "tile storage changed the answer");
    assert_eq!(sp.r.n1(), 1, "distmult cores must stay 1×k diagonals");
}

// ---------------------------------------------------------------------
// Logistic: Bernoulli MU descends
// ---------------------------------------------------------------------

#[test]
fn logistic_error_monotone_nonincreasing() {
    let (n, m, k) = (16, 2, 2);
    let x = synthetic::block_tensor(n, m, k, 0.01, 2400).x;
    let (a0, r0) = given_init(n, k, m, ModelKind::Logistic, 2401);
    // checkpoints along one deterministic trajectory (shared init). The
    // MU rule descends the Bernoulli objective; the reported Frobenius
    // error against σ(ARAᵀ) tracks it with a little room for the
    // metric/objective gap between nearby checkpoints.
    let mut errs = Vec::new();
    for iters in [5usize, 10, 20, 40] {
        let results =
            run_family_on(RankCtx::create_all(1), &x, ModelKind::Logistic, &a0, &r0, iters);
        let (_, _, a, rel) = &results[0];
        assert!(rel.is_finite(), "logistic error diverged at {iters} iters");
        if let Some(&prev) = errs.last() {
            assert!(
                *rel <= prev + 5e-2,
                "logistic error rose at {iters} iters: {prev} -> {rel}"
            );
        }
        errs.push(*rel);
        assert!(
            a.as_slice().iter().all(|&v| v >= 0.0),
            "logistic factors left the non-negative orthant"
        );
    }
    assert!(
        errs[errs.len() - 1] <= errs[0] + 1e-3,
        "no overall descent: {errs:?}"
    );
}

// ---------------------------------------------------------------------
// transports: the family axis is orthogonal to the transport axis
// ---------------------------------------------------------------------

#[test]
fn families_agree_across_transports() {
    let (n, m, k, iters, p) = (12, 2, 2, 6, 4);
    let x = synthetic::planted_tensor(n, m, k, 0.0, 2500).x;
    for kind in [ModelKind::DistMult, ModelKind::Logistic] {
        let (a0, r0) = given_init(n, k, m, kind, 2501);
        let inproc = run_family_on(RankCtx::create_all(p), &x, kind, &a0, &r0, iters);
        let tcp_ctxs: Vec<RankCtx> = loopback_meshes(p, TcpConfig::default())
            .expect("loopback mesh")
            .into_iter()
            .map(|mesh| rank_ctx_from_mesh(mesh, Grid::new(p)).expect("tcp rank ctx"))
            .collect();
        let tcp = run_family_on(tcp_ctxs, &x, kind, &a0, &r0, iters);
        let (a_in, e_in) = assemble_a(&inproc, n, k, p);
        let (a_tcp, e_tcp) = assemble_a(&tcp, n, k, p);
        drescal::testing::assert_close(a_tcp.as_slice(), a_in.as_slice(), 1e-6);
        assert!(
            (e_tcp - e_in).abs() < 1e-6,
            "{}: transport changed the answer ({e_in} vs {e_tcp})",
            kind.as_str()
        );
    }
}

// ---------------------------------------------------------------------
// lifecycle: train → export → persist → serve, per family
// ---------------------------------------------------------------------

#[test]
fn every_family_survives_train_export_serve_roundtrip() {
    let (n, m, k) = (16, 2, 2);
    let planted = synthetic::block_tensor(n, m, k, 0.01, 2600);
    let data = JobData::dense(planted.x);
    for kind in [ModelKind::Rescal, ModelKind::DistMult, ModelKind::Logistic] {
        let mut engine = Engine::new(EngineConfig::new(4).with_model(kind)).unwrap();
        let report = engine.factorize(&data, &RescalOptions::new(k, 40), 17).unwrap();
        assert_eq!(report.model, kind, "report not stamped with the family");
        assert_eq!(report.r.n1(), kind.core_rows(k), "wrong core shape for {}", kind.as_str());
        assert!(report.rel_error.is_finite());

        let exported = engine.export_model(&Report::Factorize(report)).unwrap();
        let path = std::env::temp_dir().join(format!(
            "drescal_family_{}_{}.json",
            kind.as_str(),
            std::process::id()
        ));
        exported.save(&path).unwrap();
        let model = FactorModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(model.model(), kind, "family lost in the JSON artifact");
        assert!(model.ensure_model(kind).is_ok());
        let mismatch = if kind == ModelKind::Rescal {
            ModelKind::DistMult
        } else {
            ModelKind::Rescal
        };
        let e = model.ensure_model(mismatch).unwrap_err();
        assert!(e.to_string().contains("model family mismatch"), "{e}");

        let saved = model.projection_bytes_saved();
        let mut qe = QueryEngine::new(model);
        if kind == ModelKind::DistMult {
            assert_eq!(saved, 2 * m * n * k * 4, "diagonal serving saved nothing");
        } else {
            assert_eq!(saved, 0);
        }
        assert_eq!(qe.stats().projection_bytes_saved, saved);
        let answers = qe
            .submit_batch(&[
                Query::TopObjects { s: 0, r: 0, top: 3 },
                Query::Score { s: 0, r: 0, o: 1 },
            ])
            .unwrap();
        match &answers[0] {
            Answer::TopK(hits) => {
                assert_eq!(hits.len(), 3);
                if kind == ModelKind::Logistic {
                    for h in hits {
                        assert!(
                            h.score > 0.0 && h.score < 1.0,
                            "logistic scores are probabilities, got {}",
                            h.score
                        );
                    }
                }
            }
            other => panic!("completion answered {other:?}"),
        }
        match &answers[1] {
            Answer::Score(v) => {
                assert!(v.is_finite());
                if kind == ModelKind::Logistic {
                    assert!(*v > 0.0 && *v < 1.0, "σ left (0,1): {v}");
                }
            }
            other => panic!("pointwise answered {other:?}"),
        }
    }
}

#[test]
fn legacy_artifacts_without_model_field_serve_as_rescal() {
    let planted = synthetic::block_tensor(12, 2, 2, 0.01, 2700);
    let data = JobData::dense(planted.x);
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let report = engine.factorize(&data, &RescalOptions::new(2, 20), 3).unwrap();
    let exported = engine.export_model(&Report::Factorize(report)).unwrap();
    // strip the model field the way a pre-family-plane export looks
    let mut obj = match exported.to_json() {
        Json::Obj(o) => o,
        _ => unreachable!("artifacts serialize as objects"),
    };
    obj.remove("model");
    let legacy = FactorModel::from_json(&Json::Obj(obj)).unwrap();
    assert_eq!(legacy.model(), ModelKind::Rescal);
    assert_eq!(legacy.projection_bytes_saved(), 0);
    let mut qe = QueryEngine::new(legacy);
    assert!(matches!(
        qe.query(Query::TopObjects { s: 0, r: 0, top: 2 }).unwrap(),
        Answer::TopK(_)
    ));
}

// ---------------------------------------------------------------------
// typed rejections
// ---------------------------------------------------------------------

#[test]
fn nndsvd_init_is_rejected_for_non_rescal_families() {
    let planted = synthetic::block_tensor(12, 2, 2, 0.01, 2800);
    let data = JobData::dense(planted.x);
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let cfg = RescalkConfig {
        k_min: 2,
        k_max: 3,
        perturbations: 2,
        rescal_iters: 10,
        regress_iters: 5,
        seed: 1,
        init: InitStrategy::Nndsvd {
            factors: Arc::new(std::collections::BTreeMap::new()),
            jitter: 0.01,
        },
        model: ModelKind::DistMult,
        ..Default::default()
    };
    let e = engine.model_select(&data, &cfg).unwrap_err();
    assert!(e.to_string().contains("NNDSVD"), "{e}");
    // random init with the same family is fine
    let ok = RescalkConfig {
        k_min: 2,
        k_max: 3,
        perturbations: 2,
        rescal_iters: 30,
        regress_iters: 5,
        seed: 1,
        model: ModelKind::DistMult,
        ..Default::default()
    };
    let sweep = engine.model_select(&data, &ok).unwrap();
    assert_eq!(sweep.model, ModelKind::DistMult, "sweep report not stamped");
    assert_eq!(sweep.r.n1(), 1, "sweep winner must keep diagonal cores");
}
