//! Trimmed reproduction of the paper's §6.2.1 correctness study: a sweep
//! of synthetic tensors with planted latent dimensionality; RESCALk must
//! recover k_true and the features must correlate with the ground truth.
//! (The full-width 100-tensor study is `examples/model_selection_synthetic`.)

use drescal::backend::native::NativeBackend;
use drescal::backend::Workspace;
use drescal::comm::grid::run_on_grid;
use drescal::comm::{Grid, Trace};
use drescal::data::synthetic;
use drescal::linalg::pearson::best_match_correlation;
use drescal::model_selection::{rescalk_rank, InitStrategy, RescalkConfig, SelectionRule};
use drescal::rescal::LocalTile;
use drescal::tensor::Mat;

struct Case {
    n: usize,
    m: usize,
    k_true: usize,
    p: usize,
    seed: u64,
}

fn run_case(case: &Case) -> (usize, f32) {
    let planted = synthetic::block_tensor(case.n, case.m, case.k_true, 0.01, case.seed);
    let x = planted.x.clone();
    let cfg = RescalkConfig {
        k_min: (case.k_true - 1).max(1),
        k_max: case.k_true + 2,
        perturbations: 5,
        delta: 0.02,
        rescal_iters: 150,
        tol: 0.0,
        err_every: 25,
        regress_iters: 25,
        seed: case.seed,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let n = case.n;
    let results = run_on_grid(case.p, |ctx| {
        let (r0, r1) = ctx.grid.chunk(n, ctx.row);
        let (c0, c1) = ctx.grid.chunk(n, ctx.col);
        let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
        let mut backend = NativeBackend::new();
        let mut ws = Workspace::new();
        let mut trace = Trace::disabled();
        let out = rescalk_rank(&ctx, &tile, n, &cfg, &mut backend, &mut ws, &mut trace)
            .expect("in-process rescalk_rank");
        (ctx.row, ctx.col, out)
    });
    // assemble full A from diagonal ranks
    let grid = Grid::new(case.p);
    let k_opt = results[0].2.k_opt;
    let mut a = Mat::zeros(n, k_opt);
    for (row, col, res) in &results {
        assert_eq!(res.k_opt, k_opt, "ranks disagree on k_opt");
        if row == col {
            let (s, _) = grid.chunk(n, *row);
            for i in 0..res.a_opt_row.rows() {
                for j in 0..k_opt {
                    a[(s + i, j)] = res.a_opt_row[(i, j)];
                }
            }
        }
    }
    let corr = if k_opt == case.k_true {
        best_match_correlation(&planted.a_true, &a)
    } else {
        0.0
    };
    (k_opt, corr)
}

#[test]
fn sweep_recovers_planted_k_across_shapes_and_grids() {
    let cases = [
        Case { n: 20, m: 2, k_true: 2, p: 1, seed: 900 },
        Case { n: 24, m: 3, k_true: 3, p: 4, seed: 901 },
        Case { n: 30, m: 2, k_true: 4, p: 4, seed: 902 },
        Case { n: 27, m: 2, k_true: 3, p: 9, seed: 903 },
    ];
    let mut recovered = 0;
    for case in &cases {
        let (k_opt, corr) = run_case(case);
        eprintln!(
            "n={} m={} p={} k_true={} -> k_opt={} corr={:.3}",
            case.n, case.m, case.p, case.k_true, k_opt, corr
        );
        if k_opt == case.k_true {
            recovered += 1;
            // paper: correlation up to 0.98 for weakly correlated features
            assert!(corr > 0.8, "feature correlation {corr} too low");
        }
    }
    assert!(
        recovered >= 3,
        "only {recovered}/4 cases recovered the planted k"
    );
}

#[test]
fn higher_noise_still_recovers_k() {
    // paper's ±1% noise is mild; check robustness at 5%
    let planted = synthetic::block_tensor(24, 2, 3, 0.05, 910);
    let x = planted.x.clone();
    let cfg = RescalkConfig {
        k_min: 2,
        k_max: 5,
        perturbations: 5,
        delta: 0.02,
        rescal_iters: 150,
        tol: 0.0,
        err_every: 25,
        regress_iters: 25,
        seed: 910,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let results = run_on_grid(4, |ctx| {
        let (r0, r1) = ctx.grid.chunk(24, ctx.row);
        let (c0, c1) = ctx.grid.chunk(24, ctx.col);
        let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
        let mut backend = NativeBackend::new();
        let mut ws = Workspace::new();
        let mut trace = Trace::disabled();
        rescalk_rank(&ctx, &tile, 24, &cfg, &mut backend, &mut ws, &mut trace)
            .expect("in-process rescalk_rank")
            .k_opt
    });
    assert_eq!(results[0], 3, "noise broke k recovery");
}
