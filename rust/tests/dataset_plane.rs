//! Dataset data-plane integration: register once, compute many.
//!
//! Covers the tile-reuse guarantee (the acceptance criterion of this
//! refactor: N consecutive jobs on one handle perform exactly p tile
//! materializations), rank-local synthetic generation equivalence with
//! leader-materialized data, sparse end-to-end jobs through the engine,
//! and the typed error paths of the registry.

use drescal::coordinator::JobData;
use drescal::data::synthetic::SyntheticSpec;
use drescal::data::synthetic;
use drescal::engine::{DatasetSpec, Engine, EngineConfig};
use drescal::model_selection::RescalkConfig;
use drescal::rescal::RescalOptions;
use drescal::tensor::Csr;

/// The headline counter-asserted guarantee: one `load_dataset` performs
/// exactly p tile extractions, and any number of subsequent jobs on the
/// handle performs zero more.
#[test]
fn repeated_jobs_on_one_handle_tile_exactly_once_per_rank() {
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    assert_eq!(engine.stats().tile_builds, 0);

    let planted = synthetic::block_tensor(24, 2, 3, 0.01, 800);
    let handle = engine.load_dataset(JobData::dense(planted.x.clone())).unwrap();
    assert_eq!(engine.stats().tile_builds, 4, "one tile per rank at load");

    // N = 3 consecutive factorize jobs + 1 model-select on the same handle
    for seed in 0..3 {
        let report = engine.factorize(handle, &RescalOptions::new(3, 40), seed).unwrap();
        assert_eq!(report.a.shape(), (24, 3));
    }
    let cfg = RescalkConfig {
        k_min: 2,
        k_max: 3,
        perturbations: 3,
        rescal_iters: 60,
        regress_iters: 10,
        seed: 2,
        ..Default::default()
    };
    engine.model_select(handle, &cfg).unwrap();

    let stats = engine.stats();
    assert_eq!(
        stats.tile_builds, 4,
        "{} tile builds after 4 jobs — jobs must reuse resident tiles",
        stats.tile_builds
    );
    assert_eq!(stats.jobs_completed, 4);
    assert_eq!(stats.datasets_resident, 1);

    // a second dataset pays its own p extractions, nothing more
    let other = engine
        .load_dataset(DatasetSpec::from(SyntheticSpec::dense(16, 2, 2, 9)))
        .unwrap();
    engine.factorize(other, &RescalOptions::new(2, 20), 1).unwrap();
    assert_eq!(engine.stats().tile_builds, 8);
    assert_eq!(engine.stats().datasets_resident, 2);
}

/// The inline compat shim caches by `Arc` identity: resubmitting the same
/// `JobData` value must not re-tile, while a distinct tensor must.
#[test]
fn inline_job_data_is_registered_once_per_tensor() {
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    let data = JobData::dense(synthetic::block_tensor(16, 2, 2, 0.01, 801).x);
    engine.factorize(&data, &RescalOptions::new(2, 20), 1).unwrap();
    engine.factorize(&data, &RescalOptions::new(2, 20), 2).unwrap();
    assert_eq!(engine.stats().tile_builds, 4, "same JobData re-tiled");
    let fresh = JobData::dense(synthetic::block_tensor(16, 2, 2, 0.01, 802).x);
    engine.factorize(&fresh, &RescalOptions::new(2, 20), 1).unwrap();
    assert_eq!(engine.stats().tile_builds, 8, "distinct JobData must re-tile");
}

/// Auto-registrations are LRU-bounded: a fresh-tensor-per-job loop (the
/// pre-data-plane pattern) must not grow resident rank memory without
/// bound, while explicitly loaded handles survive the churn.
#[test]
fn inline_auto_registrations_are_evicted_lru() {
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
    let pinned = engine
        .load_dataset(JobData::dense(synthetic::block_tensor(12, 2, 2, 0.01, 820).x))
        .unwrap();
    // 7 distinct inline tensors, each submitted once
    for seed in 0..7 {
        let data = JobData::dense(synthetic::block_tensor(12, 2, 2, 0.01, 830 + seed).x);
        engine.factorize(&data, &RescalOptions::new(2, 5), seed).unwrap();
    }
    let stats = engine.stats();
    // every distinct tensor tiled once (p = 1): the pinned one + 7 inline
    assert_eq!(stats.tile_builds, 8);
    // ...but only the LRU-bounded tail stays resident, plus the pinned one
    assert!(
        stats.datasets_resident <= 5,
        "{} datasets resident — inline auto-registrations must be evicted",
        stats.datasets_resident
    );
    // the explicitly loaded handle was never evicted
    assert!(engine.dataset_info(pinned).is_some());
    engine.factorize(pinned, &RescalOptions::new(2, 5), 0).unwrap();
}

/// Rank-local synthetic generation is equivalent to loading the
/// leader-materialized tensor: identical tiles ⇒ identical factorization.
#[test]
fn rank_local_generation_matches_leader_materialized_run() {
    let spec = SyntheticSpec::dense(20, 2, 3, 803);
    let mut engine = Engine::new(EngineConfig::new(4)).unwrap();
    // leader path: materialize the full tensor, register it in-memory
    let full = spec.dense_tile(0, 20, 0, 20);
    let leader = engine.load_dataset(JobData::dense(full)).unwrap();
    // rank-local path: each rank generates its own tile; the leader-side
    // spec carries block ranges only
    let local = engine.load_dataset(spec).unwrap();
    let opts = RescalOptions::new(3, 50);
    let a = engine.factorize(leader, &opts, 7).unwrap();
    let b = engine.factorize(local, &opts, 7).unwrap();
    assert_eq!(a.a.shape(), b.a.shape());
    assert!((a.rel_error - b.rel_error).abs() < 1e-6, "{} vs {}", a.rel_error, b.rel_error);
    for (x, y) in a.a.as_slice().iter().zip(b.a.as_slice()) {
        assert!((x - y).abs() < 1e-5, "factor mismatch: {x} vs {y}");
    }
}

/// Same equivalence on the CSR path, plus an engine-level sparse
/// end-to-end model-selection job (engine sweeps used to be dense-only).
#[test]
fn sparse_end_to_end_through_the_data_plane() {
    let spec = SyntheticSpec::sparse(24, 2, 3, 0.25, 804);
    let mut engine = Engine::new(EngineConfig::new(4).with_trace(true)).unwrap();
    // leader-materialized CSR set vs rank-local generation
    let full: Vec<Csr> = spec.sparse_tile(0, 24, 0, 24);
    let leader = engine.load_dataset(JobData::sparse(full)).unwrap();
    let local = engine.load_dataset(spec).unwrap();
    let info = engine.dataset_info(local).unwrap();
    assert!(info.sparse);
    assert_eq!((info.n, info.m), (24, 2));
    assert!(info.resident_bytes > 0);

    let opts = RescalOptions::new(3, 40);
    let a = engine.factorize(leader, &opts, 5).unwrap();
    let b = engine.factorize(local, &opts, 5).unwrap();
    assert!((a.rel_error - b.rel_error).abs() < 1e-6);
    let sparse_bytes: usize = b
        .traces
        .iter()
        .map(|t| t.bytes(drescal::comm::CommOp::MatrixMulSparse))
        .sum();
    assert!(sparse_bytes > 0, "sparse path not exercised");

    // full sparse model-selection sweep on the resident handle
    let cfg = RescalkConfig {
        k_min: 2,
        k_max: 4,
        perturbations: 3,
        rescal_iters: 60,
        regress_iters: 10,
        seed: 3,
        ..Default::default()
    };
    let sweep = engine.model_select(local, &cfg).unwrap();
    assert_eq!(sweep.scores.len(), 3);
    assert_eq!(sweep.a.shape().0, 24);
    assert!(sweep.scores.iter().all(|s| s.rel_error.is_finite()));
}

/// Registry error paths are typed and do not poison the pool.
#[test]
fn data_plane_errors_are_typed_and_recoverable() {
    let mut engine = Engine::new(EngineConfig::new(1)).unwrap();

    // empty sparse relation list: used to panic inside a rank thread
    let e = engine.load_dataset(JobData::sparse(vec![])).unwrap_err();
    assert!(e.to_string().contains("no relation slices"), "{e}");

    // mismatched slice shapes
    let e = engine
        .load_dataset(JobData::sparse(vec![
            Csr::from_triplets(4, 4, vec![(0, 0, 1.0)]),
            Csr::from_triplets(6, 6, vec![(1, 1, 1.0)]),
        ]))
        .unwrap_err();
    assert!(e.to_string().contains("slice 1"), "{e}");

    // unload, then submit on the dangling handle
    let handle = engine.load_dataset(SyntheticSpec::dense(8, 2, 2, 1)).unwrap();
    engine.unload_dataset(handle).unwrap();
    assert_eq!(engine.dataset_info(handle), None);
    let e = engine.factorize(handle, &RescalOptions::new(2, 5), 1).unwrap_err();
    assert!(e.to_string().contains("unknown dataset handle"), "{e}");
    let e = engine.unload_dataset(handle).unwrap_err();
    assert!(e.to_string().contains("unknown dataset handle"), "{e}");

    // the pool survived all of the above: a good job still runs
    let ok = engine.load_dataset(SyntheticSpec::dense(8, 2, 2, 2)).unwrap();
    let report = engine.factorize(ok, &RescalOptions::new(2, 10), 1).unwrap();
    assert!(report.rel_error.is_finite());
    assert_eq!(engine.stats().datasets_resident, 1);
}
