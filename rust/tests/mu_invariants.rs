//! Property tests on the multiplicative-update invariants that the
//! paper's convergence argument rests on (Lee & Seung monotonicity,
//! non-negativity closure, scale consistency), across random shapes,
//! ranks, grids, and data — the proptest-style coverage layer
//! (`drescal::testing::property`, seeded and replayable).

use drescal::backend::native::NativeBackend;
use drescal::backend::Workspace;
use drescal::comm::grid::run_on_grid;
use drescal::comm::Trace;
use drescal::data::synthetic;
use drescal::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use drescal::rescal::{rescal_seq, Init, LocalTile, ModelKind, RescalOptions};
use drescal::tensor::ops::is_nonnegative;
use drescal::tensor::Tensor3;
use drescal::testing::property;

#[test]
fn mu_error_never_increases_random_tensors() {
    // monotone descent on arbitrary non-negative data (not just planted)
    property(6, |rng| {
        let n = 8 + rng.below(12);
        let m = 1 + rng.below(3);
        let k = 2 + rng.below(3);
        let x = Tensor3::random_uniform(n, n, m, 0.0, 1.0, rng);
        let seed = rng.next_u64();
        let e5 = rescal_seq(&x, &RescalOptions::new(k, 5), Init::Random, seed).rel_error;
        let e25 = rescal_seq(&x, &RescalOptions::new(k, 25), Init::Random, seed).rel_error;
        let e100 = rescal_seq(&x, &RescalOptions::new(k, 100), Init::Random, seed).rel_error;
        assert!(e25 <= e5 + 1e-4, "5->{e5}, 25->{e25}");
        assert!(e100 <= e25 + 1e-4, "25->{e25}, 100->{e100}");
    });
}

#[test]
fn factors_nonnegative_any_shape() {
    property(6, |rng| {
        let n = 6 + rng.below(10);
        let m = 1 + rng.below(4);
        let k = 1 + rng.below(4);
        let x = Tensor3::random_uniform(n, n, m, 0.0, 2.0, rng);
        let out = rescal_seq(&x, &RescalOptions::new(k, 20), Init::Random, rng.next_u64());
        assert!(is_nonnegative(&out.a));
        for t in 0..m {
            assert!(is_nonnegative(out.r.slice(t)));
        }
    });
}

#[test]
fn scale_equivariance() {
    // scaling X by c scales the optimal R by c (A is normalized), so the
    // relative error is invariant
    property(4, |rng| {
        let n = 10 + rng.below(8);
        let x = Tensor3::random_uniform(n, n, 2, 0.0, 1.0, rng);
        let scaled = {
            let slices = (0..2)
                .map(|t| {
                    let mut s = x.slice(t).clone();
                    s.scale(7.5);
                    s
                })
                .collect();
            Tensor3::from_slices(slices)
        };
        let seed = rng.next_u64();
        let e1 = rescal_seq(&x, &RescalOptions::new(3, 40), Init::Random, seed).rel_error;
        let e2 = rescal_seq(&scaled, &RescalOptions::new(3, 40), Init::Random, seed).rel_error;
        assert!((e1 - e2).abs() < 0.05, "rel err not scale-invariant: {e1} vs {e2}");
    });
}

#[test]
fn distributed_equals_sequential_random_configs() {
    // the central correctness property, sampled across shapes and grids
    property(4, |rng| {
        let q = 1 + rng.below(3); // 1, 2, or 3 -> p in {1, 4, 9}
        let p = q * q;
        let n = (q.max(2)) * (4 + rng.below(5)); // ensure n >= q
        let m = 1 + rng.below(3);
        let k = 2 + rng.below(3);
        let x = synthetic::planted_tensor(n, m, k, 0.0, rng.next_u64()).x;
        let (a0, r0) = Init::Random.materialize(&x, k, rng);
        let opts = RescalOptions::new(k, 8);
        let seq = rescal_seq(&x, &opts, Init::Given(a0.clone(), r0.clone()), 0);
        let a0 = std::sync::Arc::new(a0);
        let r0 = std::sync::Arc::new(r0);
        let results = run_on_grid(p, |ctx| {
            let (rs, re) = ctx.grid.chunk(n, ctx.row);
            let (cs, ce) = ctx.grid.chunk(n, ctx.col);
            let tile = LocalTile::Dense(x.tile(rs, re, cs, ce));
            let cfg = DistRescalConfig {
                opts: opts.clone(),
                init: DistInit::Given(a0.clone(), r0.clone()),
                n,
                model: ModelKind::Rescal,
            };
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::disabled();
            let out = rescal_rank(&ctx, &tile, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescal_rank");
            (ctx.row, ctx.col, out)
        });
        for (row, col, out) in &results {
            if row == col {
                let (s, _) = drescal::comm::Grid::new(p).chunk(n, *row);
                for i in 0..out.a_row.rows() {
                    for j in 0..k {
                        let got = out.a_row[(i, j)];
                        let want = seq.a[(s + i, j)];
                        assert!(
                            (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                            "A[{},{}]: dist {} vs seq {} (n={n}, p={p})",
                            s + i,
                            j,
                            got,
                            want
                        );
                    }
                }
                assert!((out.rel_error - seq.rel_error).abs() < 1e-3);
            }
        }
    });
}

#[test]
fn perturbation_preserves_solution_neighborhood() {
    // a δ-perturbed tensor must factor to nearly the same error — the
    // premise of the stability method
    property(4, |rng| {
        let n = 12 + rng.below(8);
        let x = synthetic::block_tensor(n, 2, 2, 0.01, rng.next_u64()).x;
        let seed = rng.next_u64();
        let base = rescal_seq(&x, &RescalOptions::new(2, 120), Init::Random, seed).rel_error;
        // perturb ±2%
        let perturbed = {
            let slices = (0..2)
                .map(|t| {
                    let mut s = x.slice(t).clone();
                    for v in s.as_mut_slice() {
                        *v *= rng.uniform_range(0.98, 1.02);
                    }
                    s
                })
                .collect();
            Tensor3::from_slices(slices)
        };
        let pert =
            rescal_seq(&perturbed, &RescalOptions::new(2, 120), Init::Random, seed).rel_error;
        assert!(
            (base - pert).abs() < 0.05,
            "perturbation destabilized the factorization: {base} vs {pert}"
        );
    });
}
