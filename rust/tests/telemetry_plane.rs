//! Telemetry plane integration: a telemetry-disabled run must perform
//! zero obs-plane allocations and tracing must never perturb the math
//! (bit-identical factors); a real TCP cluster with `--trace-out` must
//! export a valid Chrome trace carrying phase spans from every rank in
//! every OS process, and `drescal trace-summary` must agree with the
//! trace's own totals.
//!
//! Live-plane integration: a leader started with `--status-port` must
//! serve `/healthz`, `/metrics` (Prometheus text), `/progress`
//! (advancing iteration counter), and `/trace` over plain HTTP while
//! the job runs, and `drescal monitor` must render live rows from it;
//! killing one worker mid-job must leave that worker's pre-crash spans
//! in the final `--trace-out` artifact via the leader's telemetry hub.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use drescal::data::synthetic::SyntheticSpec;
use drescal::engine::{Engine, EngineConfig};
use drescal::json::Json;
use drescal::rescal::RescalOptions;

fn factor_bits(report: &drescal::coordinator::RescalReport) -> Vec<u32> {
    let mut bits: Vec<u32> = report.a.as_slice().iter().map(|v| v.to_bits()).collect();
    for s in report.r.slices() {
        bits.extend(s.as_slice().iter().map(|v| v.to_bits()));
    }
    bits
}

/// Telemetry off is the default, and it must cost nothing: the obs
/// allocation counter is untouched across a whole factorization, the
/// report ships no timeline, and turning tracing on afterwards produces
/// bit-identical factors (spans observe the math, never steer it).
///
/// `obs::alloc_count` is process-global, so this test is the only one in
/// this binary that may touch obs-plane code in-process — the cluster
/// test below drives subprocesses and parses their JSON by hand.
#[test]
fn disabled_telemetry_allocates_nothing_and_changes_nothing() {
    let spec = || SyntheticSpec::dense(24, 2, 3, 9);
    let opts = RescalOptions::new(3, 12);

    let mut plain = Engine::new(EngineConfig::new(4)).unwrap();
    let data = plain.load_dataset(spec()).unwrap();
    let before = drescal::obs::alloc_count();
    let report = plain.factorize(data, &opts, 9).unwrap();
    assert_eq!(
        drescal::obs::alloc_count(),
        before,
        "telemetry-disabled factorize allocated on the obs plane"
    );
    assert!(report.timeline.is_empty(), "untraced run must not ship a timeline");

    let mut traced = Engine::new(EngineConfig::new(4).with_trace(true)).unwrap();
    let tdata = traced.load_dataset(spec()).unwrap();
    let treport = traced.factorize(tdata, &opts, 9).unwrap();
    assert_eq!(treport.timeline.len(), 4, "one timeline per rank");
    for t in &treport.timeline {
        assert!(
            t.spans.iter().any(|s| s.cat == "phase"),
            "rank {} recorded no phase spans",
            t.rank
        );
    }
    assert_eq!(
        factor_bits(&report),
        factor_bits(&treport),
        "tracing changed the factors"
    );
}

// ---------------------------------------------------------------------
// multi-process: real `drescal train --trace-out` over a TCP cluster
// ---------------------------------------------------------------------

fn drescal() -> Command {
    Command::new(env!("CARGO_BIN_EXE_drescal"))
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("drescal_telemetry_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Poll until the leader writes its bound address to the port file.
fn wait_port_file(path: &Path) -> String {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let t = s.trim();
            if !t.is_empty() {
                return t.to_string();
            }
        }
        assert!(
            Instant::now() < deadline,
            "leader never wrote its port file {}",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn spawn_worker(addr: &str) -> Child {
    drescal()
        .args(["worker", "--connect", addr])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn drescal worker")
}

/// Wait for a child with a deadline; kill and fail if it wedges.
fn reap(mut child: Child, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(25));
            }
            _ => {
                let _ = child.kill();
                let _ = child.wait();
                panic!("{what} did not exit after the leader finished");
            }
        }
    }
}

fn combined(out: &std::process::Output) -> String {
    format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    )
}

/// A 2×2 TCP cluster (leader + 3 worker processes) trained with
/// `--trace-out` must export one Chrome trace covering the whole
/// cluster: all 4 rank tracks, 4 distinct OS pids, phase spans on every
/// track, and per-op totals that `trace-summary` reproduces exactly.
#[test]
fn tcp_cluster_trace_covers_every_rank_and_process() {
    let dir = tmpdir("cluster");
    let port_file = dir.join("leader.addr");
    let trace_path = dir.join("trace.json");
    let leader = drescal()
        .arg("train")
        .args(["--data", "synthetic", "--n", "24", "--m", "2", "--k-true", "2"])
        .args(["--density", "0.3", "--k", "2", "--iters", "5", "--seed", "5"])
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .args(["--trace-out", trace_path.to_str().unwrap()])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn leader");
    let addr = wait_port_file(&port_file);
    let workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();
    let out = leader.wait_with_output().expect("leader run");
    let text = combined(&out);
    for w in workers {
        reap(w, "worker");
    }
    assert!(out.status.success(), "leader failed:\n{text}");
    assert!(
        text.contains("from 4 rank(s)"),
        "leader did not report a 4-rank trace export:\n{text}"
    );

    // the exported file is a valid Chrome trace with complete events
    // from every rank of every process
    let raw = std::fs::read_to_string(&trace_path).expect("trace file written");
    let v = Json::parse(&raw).expect("trace must be valid JSON");
    let events = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("trace must carry a traceEvents array");
    let mut pids: BTreeSet<u64> = BTreeSet::new();
    let mut tids: BTreeSet<u64> = BTreeSet::new();
    let mut phase_tids: BTreeSet<u64> = BTreeSet::new();
    let mut x_events: u64 = 0;
    let mut total_bytes: u64 = 0;
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        x_events += 1;
        let pid = e.get("pid").and_then(Json::as_f64).expect("event pid") as u64;
        let tid = e.get("tid").and_then(Json::as_f64).expect("event tid") as u64;
        pids.insert(pid);
        tids.insert(tid);
        if e.get("cat").and_then(Json::as_str) == Some("phase") {
            phase_tids.insert(tid);
        }
        total_bytes += e
            .get("args")
            .and_then(|a| a.get("bytes"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
    }
    assert!(x_events > 0, "trace holds no complete events");
    assert_eq!(tids, (0..4).collect(), "missing rank tracks in the trace");
    assert_eq!(
        pids.len(),
        4,
        "expected 4 distinct OS pids (leader + 3 workers), got {pids:?}"
    );
    assert_eq!(
        phase_tids.len(),
        4,
        "phase spans missing from some rank: only tids {phase_tids:?} have them"
    );

    // trace-summary must reproduce the trace's own totals: summed row
    // counts equal the X-event count, and the total row's byte column
    // equals the sum of every event's byte payload
    let summary = drescal()
        .args(["trace-summary", trace_path.to_str().unwrap()])
        .output()
        .unwrap();
    let stext = combined(&summary);
    assert!(summary.status.success(), "trace-summary failed:\n{stext}");
    let mut row_counts: u64 = 0;
    for line in stext.lines().skip(1) {
        // skip the total row and the ring-drop footer ("recorded N
        // sample(s) in R row(s); D span(s) dropped ...")
        if line.starts_with("total") || line.starts_with("recorded") {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        // data rows are [cat, op, count, seconds, bytes]
        if toks.len() == 5 {
            row_counts += toks[2].parse::<u64>().unwrap_or(0);
        }
    }
    assert_eq!(row_counts, x_events, "summary counts disagree with the trace:\n{stext}");
    let total_line = stext
        .lines()
        .find(|l| l.starts_with("total"))
        .unwrap_or_else(|| panic!("no total row in summary:\n{stext}"));
    assert_eq!(
        total_line.split_whitespace().last().unwrap(),
        total_bytes.to_string(),
        "summary byte total disagrees with the trace:\n{stext}"
    );
    // the ring-drop footer is always present (0 drops on a run this small)
    assert!(
        stext.contains("span(s) dropped"),
        "summary lost its ring-drop footer:\n{stext}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// live plane: --status-port endpoint + drescal monitor + chaos
// ---------------------------------------------------------------------

/// Reserve an ephemeral port by binding and dropping a listener. A tiny
/// race remains between drop and the leader's bind, acceptable in CI.
fn free_port() -> u16 {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = l.local_addr().unwrap().port();
    drop(l);
    port
}

/// Poll `/progress` until `pred` accepts the parsed document.
fn wait_progress(addr: &str, what: &str, pred: impl Fn(&Json) -> bool) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Ok(body) = drescal::obs::http_get(addr, "/progress", Duration::from_secs(2)) {
            let v = Json::parse(&body).expect("/progress must be valid JSON");
            if pred(&v) {
                return v;
            }
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what} at {addr}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn progress_iter(v: &Json) -> i64 {
    v.get("iter").and_then(Json::as_f64).map_or(-1, |x| x as i64)
}

/// A real leader + 3 TCP workers started with `--status-port` must serve
/// all four routes over plain HTTP while the job runs: `/healthz` says
/// ok, `/metrics` carries the advertised Prometheus families, `/progress`
/// reports an advancing iteration counter, `/trace` is a Chrome trace of
/// the spans absorbed so far — and `drescal monitor` pointed at the same
/// endpoint renders at least one live iteration row.
#[test]
fn status_endpoint_serves_live_progress_and_monitor_renders_it() {
    let dir = tmpdir("live");
    let port_file = dir.join("leader.addr");
    let status_port = free_port();
    let status_addr = format!("127.0.0.1:{status_port}");
    let leader = drescal()
        .arg("train")
        .args(["--data", "synthetic", "--n", "48", "--m", "2", "--k-true", "3"])
        .args(["--density", "0.3", "--k", "3", "--iters", "3000", "--seed", "7"])
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .args(["--status-port", &status_port.to_string()])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn leader");
    let addr = wait_port_file(&port_file);
    let workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();

    // liveness first: the endpoint is up before the job's first iteration
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        match drescal::obs::http_get(&status_addr, "/healthz", Duration::from_secs(2)) {
            Ok(body) => {
                assert_eq!(body, "ok\n");
                break;
            }
            Err(_) => {
                assert!(Instant::now() < deadline, "status endpoint never came up");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }

    // the iteration counter must actually advance between two samples
    let first = wait_progress(&status_addr, "first iteration", |v| progress_iter(v) >= 0);
    let start = progress_iter(&first);
    let v = wait_progress(&status_addr, "an advancing iter", |v| progress_iter(v) > start);
    assert_eq!(v.get("job").and_then(Json::as_str), Some("factorize"));
    assert_eq!(v.get("done").and_then(Json::as_bool), Some(false));
    assert!(
        v.get("wire_bytes").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
        "TCP cluster iterations must move wire bytes"
    );

    // Prometheus exposition: the advertised families, well-formed lines
    let metrics =
        drescal::obs::http_get(&status_addr, "/metrics", Duration::from_secs(2)).unwrap();
    for family in [
        "drescal_job_done",
        "drescal_iterations_total",
        "drescal_wire_bytes_total",
        "drescal_phase_seconds_total",
        "drescal_kernel_info",
        "drescal_iteration_seconds_count",
    ] {
        assert!(metrics.contains(family), "/metrics lacks {family}:\n{metrics}");
    }
    for line in metrics.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let mut parts = line.rsplitn(2, ' ');
        let value = parts.next().unwrap_or("");
        assert!(
            value.parse::<f64>().is_ok(),
            "metrics line does not end in a float value: {line:?}"
        );
    }

    // /trace is already a valid Chrome trace mid-job (streamed flushes)
    let trace_body =
        drescal::obs::http_get(&status_addr, "/trace", Duration::from_secs(2)).unwrap();
    let trace = Json::parse(&trace_body).expect("/trace must be valid JSON");
    assert!(
        !trace.get("traceEvents").and_then(Json::as_arr).expect("traceEvents").is_empty(),
        "mid-job /trace carries no events"
    );

    // the monitor subcommand renders live rows from the same endpoint
    // and exits cleanly when the job (and its endpoint) completes
    let monitor = drescal()
        .args(["monitor", &status_addr, "--interval-ms", "50"])
        .output()
        .expect("run drescal monitor");
    let mtext = combined(&monitor);
    assert!(monitor.status.success(), "monitor failed:\n{mtext}");
    assert!(mtext.contains("iter"), "monitor printed no header:\n{mtext}");
    let rows = mtext
        .lines()
        .filter(|l| l.trim_start().chars().next().is_some_and(|c| c.is_ascii_digit()))
        .count();
    assert!(rows >= 1, "monitor rendered no iteration rows:\n{mtext}");

    let out = leader.wait_with_output().expect("leader run");
    let text = combined(&out);
    for w in workers {
        reap(w, "worker");
    }
    assert!(out.status.success(), "leader failed:\n{text}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos: kill one TCP worker mid-job on a recovery-enabled cluster with
/// `--status-port` and `--trace-out`. The per-iteration telemetry flush
/// means the leader's hub already holds the victim's pre-crash spans, so
/// after recovery (replacement worker, job rerun) the final trace file
/// must contain spans from 5 distinct OS pids — leader, two survivors,
/// the replacement, and the dead worker.
#[test]
fn killed_workers_pre_crash_spans_survive_into_the_final_trace() {
    let dir = tmpdir("live_chaos");
    let port_file = dir.join("leader.addr");
    let trace_path = dir.join("trace.json");
    let status_port = free_port();
    let status_addr = format!("127.0.0.1:{status_port}");
    let leader = drescal()
        .arg("train")
        .args(["--data", "synthetic", "--n", "48", "--m", "2", "--k-true", "3"])
        .args(["--density", "0.3", "--k", "3", "--iters", "2000", "--seed", "11"])
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .args(["--comm-timeout-ms", "2000", "--max-replacements", "1"])
        .args(["--status-port", &status_port.to_string()])
        .args(["--trace-out", trace_path.to_str().unwrap()])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn leader");
    let addr = wait_port_file(&port_file);
    let mut workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();

    // let a few iterations flush so the victim's spans reach the hub
    wait_progress(&status_addr, "3 flushed iterations", |v| progress_iter(v) >= 3);
    let mut victim = workers.remove(1);
    let victim_pid = u64::from(victim.id());
    victim.kill().unwrap();
    let _ = victim.wait();
    workers.push(spawn_worker(&addr));

    let out = leader.wait_with_output().expect("leader run");
    let text = combined(&out);
    for w in workers {
        reap(w, "worker");
    }
    assert!(out.status.success(), "leader failed:\n{text}");
    assert!(
        text.contains("recovered at epoch"),
        "worker kill was not detected/recovered:\n{text}"
    );

    let raw = std::fs::read_to_string(&trace_path).expect("trace file written");
    let v = Json::parse(&raw).expect("trace must be valid JSON");
    let mut pids: BTreeSet<u64> = BTreeSet::new();
    for e in v.get("traceEvents").and_then(Json::as_arr).expect("traceEvents") {
        if e.get("ph").and_then(Json::as_str) == Some("X") {
            pids.insert(e.get("pid").and_then(Json::as_f64).expect("event pid") as u64);
        }
    }
    assert!(
        pids.contains(&victim_pid),
        "dead worker pid {victim_pid} lost from the final trace; pids present: {pids:?}"
    );
    assert_eq!(
        pids.len(),
        5,
        "expected 5 pids (leader + 2 survivors + replacement + victim), got {pids:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
