//! Telemetry plane integration: a telemetry-disabled run must perform
//! zero obs-plane allocations and tracing must never perturb the math
//! (bit-identical factors); a real TCP cluster with `--trace-out` must
//! export a valid Chrome trace carrying phase spans from every rank in
//! every OS process, and `drescal trace-summary` must agree with the
//! trace's own totals.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use drescal::data::synthetic::SyntheticSpec;
use drescal::engine::{Engine, EngineConfig};
use drescal::json::Json;
use drescal::rescal::RescalOptions;

fn factor_bits(report: &drescal::coordinator::RescalReport) -> Vec<u32> {
    let mut bits: Vec<u32> = report.a.as_slice().iter().map(|v| v.to_bits()).collect();
    for s in report.r.slices() {
        bits.extend(s.as_slice().iter().map(|v| v.to_bits()));
    }
    bits
}

/// Telemetry off is the default, and it must cost nothing: the obs
/// allocation counter is untouched across a whole factorization, the
/// report ships no timeline, and turning tracing on afterwards produces
/// bit-identical factors (spans observe the math, never steer it).
///
/// `obs::alloc_count` is process-global, so this test is the only one in
/// this binary that may touch obs-plane code in-process — the cluster
/// test below drives subprocesses and parses their JSON by hand.
#[test]
fn disabled_telemetry_allocates_nothing_and_changes_nothing() {
    let spec = || SyntheticSpec::dense(24, 2, 3, 9);
    let opts = RescalOptions::new(3, 12);

    let mut plain = Engine::new(EngineConfig::new(4)).unwrap();
    let data = plain.load_dataset(spec()).unwrap();
    let before = drescal::obs::alloc_count();
    let report = plain.factorize(data, &opts, 9).unwrap();
    assert_eq!(
        drescal::obs::alloc_count(),
        before,
        "telemetry-disabled factorize allocated on the obs plane"
    );
    assert!(report.timeline.is_empty(), "untraced run must not ship a timeline");

    let mut traced = Engine::new(EngineConfig::new(4).with_trace(true)).unwrap();
    let tdata = traced.load_dataset(spec()).unwrap();
    let treport = traced.factorize(tdata, &opts, 9).unwrap();
    assert_eq!(treport.timeline.len(), 4, "one timeline per rank");
    for t in &treport.timeline {
        assert!(
            t.spans.iter().any(|s| s.cat == "phase"),
            "rank {} recorded no phase spans",
            t.rank
        );
    }
    assert_eq!(
        factor_bits(&report),
        factor_bits(&treport),
        "tracing changed the factors"
    );
}

// ---------------------------------------------------------------------
// multi-process: real `drescal train --trace-out` over a TCP cluster
// ---------------------------------------------------------------------

fn drescal() -> Command {
    Command::new(env!("CARGO_BIN_EXE_drescal"))
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("drescal_telemetry_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Poll until the leader writes its bound address to the port file.
fn wait_port_file(path: &Path) -> String {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let t = s.trim();
            if !t.is_empty() {
                return t.to_string();
            }
        }
        assert!(
            Instant::now() < deadline,
            "leader never wrote its port file {}",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn spawn_worker(addr: &str) -> Child {
    drescal()
        .args(["worker", "--connect", addr])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn drescal worker")
}

/// Wait for a child with a deadline; kill and fail if it wedges.
fn reap(mut child: Child, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(25));
            }
            _ => {
                let _ = child.kill();
                let _ = child.wait();
                panic!("{what} did not exit after the leader finished");
            }
        }
    }
}

fn combined(out: &std::process::Output) -> String {
    format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    )
}

/// A 2×2 TCP cluster (leader + 3 worker processes) trained with
/// `--trace-out` must export one Chrome trace covering the whole
/// cluster: all 4 rank tracks, 4 distinct OS pids, phase spans on every
/// track, and per-op totals that `trace-summary` reproduces exactly.
#[test]
fn tcp_cluster_trace_covers_every_rank_and_process() {
    let dir = tmpdir("cluster");
    let port_file = dir.join("leader.addr");
    let trace_path = dir.join("trace.json");
    let leader = drescal()
        .arg("train")
        .args(["--data", "synthetic", "--n", "24", "--m", "2", "--k-true", "2"])
        .args(["--density", "0.3", "--k", "2", "--iters", "5", "--seed", "5"])
        .args(["--workers", "3", "--listen", "127.0.0.1:0"])
        .args(["--port-file", port_file.to_str().unwrap()])
        .args(["--trace-out", trace_path.to_str().unwrap()])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn leader");
    let addr = wait_port_file(&port_file);
    let workers: Vec<Child> = (0..3).map(|_| spawn_worker(&addr)).collect();
    let out = leader.wait_with_output().expect("leader run");
    let text = combined(&out);
    for w in workers {
        reap(w, "worker");
    }
    assert!(out.status.success(), "leader failed:\n{text}");
    assert!(
        text.contains("from 4 rank(s)"),
        "leader did not report a 4-rank trace export:\n{text}"
    );

    // the exported file is a valid Chrome trace with complete events
    // from every rank of every process
    let raw = std::fs::read_to_string(&trace_path).expect("trace file written");
    let v = Json::parse(&raw).expect("trace must be valid JSON");
    let events = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("trace must carry a traceEvents array");
    let mut pids: BTreeSet<u64> = BTreeSet::new();
    let mut tids: BTreeSet<u64> = BTreeSet::new();
    let mut phase_tids: BTreeSet<u64> = BTreeSet::new();
    let mut x_events: u64 = 0;
    let mut total_bytes: u64 = 0;
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        x_events += 1;
        let pid = e.get("pid").and_then(Json::as_f64).expect("event pid") as u64;
        let tid = e.get("tid").and_then(Json::as_f64).expect("event tid") as u64;
        pids.insert(pid);
        tids.insert(tid);
        if e.get("cat").and_then(Json::as_str) == Some("phase") {
            phase_tids.insert(tid);
        }
        total_bytes += e
            .get("args")
            .and_then(|a| a.get("bytes"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
    }
    assert!(x_events > 0, "trace holds no complete events");
    assert_eq!(tids, (0..4).collect(), "missing rank tracks in the trace");
    assert_eq!(
        pids.len(),
        4,
        "expected 4 distinct OS pids (leader + 3 workers), got {pids:?}"
    );
    assert_eq!(
        phase_tids.len(),
        4,
        "phase spans missing from some rank: only tids {phase_tids:?} have them"
    );

    // trace-summary must reproduce the trace's own totals: summed row
    // counts equal the X-event count, and the total row's byte column
    // equals the sum of every event's byte payload
    let summary = drescal()
        .args(["trace-summary", trace_path.to_str().unwrap()])
        .output()
        .unwrap();
    let stext = combined(&summary);
    assert!(summary.status.success(), "trace-summary failed:\n{stext}");
    let mut row_counts: u64 = 0;
    for line in stext.lines().skip(1) {
        if line.starts_with("total") {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        // data rows are [cat, op, count, seconds, bytes]
        if toks.len() == 5 {
            row_counts += toks[2].parse::<u64>().unwrap_or(0);
        }
    }
    assert_eq!(row_counts, x_events, "summary counts disagree with the trace:\n{stext}");
    let total_line = stext
        .lines()
        .find(|l| l.starts_with("total"))
        .unwrap_or_else(|| panic!("no total row in summary:\n{stext}"));
    assert_eq!(
        total_line.split_whitespace().last().unwrap(),
        total_bytes.to_string(),
        "summary byte total disagrees with the trace:\n{stext}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
